"""Sharded detection fleet: routing, affinity, failover, speculation.

Every in-process test drives a :class:`ShardedDetectionService` of
single-device replicas on one shared :class:`VirtualClock` — routing,
affinity, replica death, and the speculative local/remote race are all
*policy*, so one device proves them deterministically.  The one
multi-device scenario (real 8-device placement of the slot shards and
per-replica plan caches) runs in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``, same isolation
pattern as ``test_distributed.py``.
"""

import math
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.network import NetworkConfig
from repro.core.plan import HoughConfig, PipelineConfig
from repro.core.offload import SpeculativeConfig
from repro.data import make_drive_cycle, make_scenario
from repro.runtime import ServiceFaultInjector
from repro.serve.detection import (
    DetectionRequest, RequestStatus, VirtualClock,
)
from repro.serve.fleet import ShardedDetectionService

pytestmark = pytest.mark.mesh

BUCKETS = ((96, 128), (120, 160))
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _cfg() -> PipelineConfig:
    return PipelineConfig(hough=HoughConfig(compact=True, max_edges="auto"))


def make_fleet(n: int = 2, **kw) -> ShardedDetectionService:
    kw.setdefault("buckets", BUCKETS)
    kw.setdefault("batch_size", 1)
    kw.setdefault("clock", VirtualClock())
    kw.setdefault("prefetch", False)
    return ShardedDetectionService(_cfg(), n_replicas=n, **kw)


def _frame(h: int = 120, w: int = 160, seed: int = 0) -> np.ndarray:
    return make_scenario("straight", h, w, seed=seed).image


# --- routing + affinity -------------------------------------------------

def test_sessionless_load_spreads_across_replicas():
    svc = make_fleet(3)
    reqs = [DetectionRequest(uid=i, frame=_frame(seed=i)) for i in range(6)]
    for r in reqs:
        svc.submit(r)
    svc.run()
    assert all(r.ok for r in reqs)
    per_replica = [rep.service.dispatches for rep in svc.replicas]
    # queue-depth tiebreak: 6 requests over 3 idle replicas -> 2 each
    assert per_replica == [2, 2, 2]
    svc.close()


def test_session_affinity_pins_one_replica():
    svc = make_fleet(3)
    reqs = []
    for t in range(9):
        # interleave sessionless filler so the least-loaded replica keeps
        # changing — only the pin can keep the stream together
        filler = DetectionRequest(uid=100 + t, frame=_frame(seed=t))
        req = DetectionRequest(uid=t, frame=_frame(seed=t),
                               session_id="ego")
        svc.submit(filler)
        svc.submit(req)
        svc.run()
        reqs.append(req)
    assert all(r.ok for r in reqs)
    pin = svc.session_location("ego")
    assert pin is not None
    # the session's tracker exists on exactly ONE replica: the stream
    # never observed two half-blind trackers
    holders = [rep.index for rep in svc.replicas
               if "ego" in rep.service.sessions]
    assert holders == [pin]
    slo = svc.session_slo("ego")
    assert slo.submitted == 9 and slo.served == 9
    svc.close()


def test_affinity_off_splits_the_stream():
    svc = make_fleet(3, affinity=False)
    uid = 100
    for t in range(9):
        # varying filler load per round shifts which replica is least
        # loaded when the session frame arrives
        for _ in range(t % 3):
            svc.submit(DetectionRequest(uid=uid, frame=_frame(seed=t)))
            uid += 1
        svc.submit(DetectionRequest(uid=t, frame=_frame(seed=t),
                                    session_id="ego"))
        svc.run()
    # the ablation arm: load-only routing scatters the stream, so more
    # than one replica grew a tracker for it (the failure mode affinity
    # exists to prevent) — and the aggregated SLO still accounts per-frame
    holders = [rep.index for rep in svc.replicas
               if "ego" in rep.service.sessions]
    assert len(holders) >= 2
    assert svc.session_location("ego") is None
    assert svc.session_slo("ego").submitted == 9
    svc.close()


def test_session_churn_keeps_pins_consistent():
    svc = make_fleet(3)
    alive_sessions = set()
    uid = 0
    for wave in range(4):
        # three sessions arrive, the oldest one leaves each wave
        for s in range(3):
            sid = f"s{wave}-{s}"
            alive_sessions.add(sid)
            for t in range(2):
                svc.submit(DetectionRequest(
                    uid=uid, frame=_frame(seed=uid), session_id=sid))
                uid += 1
            svc.run()
        if wave:
            gone = f"s{wave - 1}-0"
            pin = svc.session_location(gone)
            svc.replicas[pin].service.end_session(gone)
            del svc._session_replica[gone]
            alive_sessions.discard(gone)
    for sid in alive_sessions:
        pin = svc.session_location(sid)
        holders = [rep.index for rep in svc.replicas
                   if sid in rep.service.sessions]
        assert holders == [pin], (sid, holders, pin)
    svc.close()


def test_migrate_session_moves_tracker_state():
    svc = make_fleet(2)
    for t in range(4):
        svc.submit(DetectionRequest(uid=t, frame=_frame(seed=0),
                                    session_id="ego"))
        svc.run()
    src = svc.session_location("ego")
    dst = 1 - src
    tracker = svc.replicas[src].service.sessions["ego"]
    ids_before = sorted(t.track_id for t in svc.session_tracks("ego"))
    assert svc.migrate_session("ego", dst)
    assert svc.session_location("ego") == dst
    # the tracker OBJECT moved — stream continuity survives the hop
    assert svc.replicas[dst].service.sessions["ego"] is tracker
    assert "ego" not in svc.replicas[src].service.sessions
    req = DetectionRequest(uid=99, frame=_frame(seed=0), session_id="ego")
    svc.submit(req)
    svc.run()
    assert req.ok
    assert svc.replicas[dst].service.dispatches > 0
    ids_after = sorted(t.track_id for t in svc.session_tracks("ego"))
    assert set(ids_before) <= set(ids_after)
    assert svc.session_slo("ego").submitted == 5
    svc.close()


def test_migrate_to_dead_replica_refused():
    svc = make_fleet(2)
    svc.submit(DetectionRequest(uid=0, frame=_frame(), session_id="ego"))
    svc.run()
    svc.kill_replica(1 - svc.session_location("ego"))
    assert not svc.migrate_session("ego", 1 - svc.session_location("ego"))
    svc.close()


# --- replica death + failover -------------------------------------------

def test_replica_death_requeues_with_original_deadlines():
    clock = VirtualClock()
    svc = make_fleet(2, clock=clock, max_queue=16)
    reqs = [DetectionRequest(uid=i, frame=_frame(seed=i), deadline_s=5.0)
            for i in range(6)]
    for r in reqs:
        svc.submit(r)
    deadlines = [r.deadline_at for r in reqs]
    clock.advance(0.5)
    victim = 0
    svc.kill_replica(victim)
    assert not svc.replicas[victim].alive
    # queued work re-routed to the survivor with its ORIGINAL absolute
    # deadline — failover must not hand a request a fresh budget
    assert svc.requeued > 0
    for r, dl in zip(reqs, deadlines):
        assert r.deadline_at == dl
    svc.run()
    assert all(r.is_terminal for r in reqs)
    assert all(r.ok for r in reqs)   # 0.5s of lost time << 5s budgets
    assert svc.replicas[1].service.dispatches == 6
    svc.close()


def test_replica_death_fails_in_flight_and_drops_pins():
    svc = make_fleet(2)
    # pin a session and put a request IN FLIGHT on its replica
    warm = DetectionRequest(uid=0, frame=_frame(), session_id="ego")
    svc.submit(warm)
    svc.run()
    pin = svc.session_location("ego")
    doomed = DetectionRequest(uid=1, frame=_frame(), session_id="ego")
    svc.submit(doomed)
    svc.step()          # dispatches on the pinned replica
    svc.kill_replica(pin)
    assert doomed.status is RequestStatus.FAILED
    assert svc.failed_on_death >= 1
    assert svc.session_failovers >= 1
    assert svc.session_location("ego") is None
    # the next frame re-pins on the survivor and rebuilds a tracker there
    nxt = DetectionRequest(uid=2, frame=_frame(), session_id="ego")
    svc.submit(nxt)
    svc.run()
    assert nxt.ok
    assert svc.session_location("ego") == 1 - pin
    assert "ego" in svc.replicas[1 - pin].service.sessions
    svc.close()


def test_replica_death_via_fault_schedule():
    faults = ServiceFaultInjector(kill_replica_at=((1, 0),))
    svc = make_fleet(2, faults=faults)
    reqs = [DetectionRequest(uid=i, frame=_frame(seed=i)) for i in range(4)]
    for r in reqs:
        svc.submit(r)
    svc.run()
    assert not svc.replicas[0].alive
    assert svc.replicas[1].alive
    # nothing hangs: every request terminated (served by the survivor,
    # or failed explicitly with the dead replica's in-flight batch)
    assert all(r.is_terminal for r in reqs)
    assert sum(r.ok for r in reqs) + svc.failed_on_death == len(reqs)
    svc.close()


def test_all_replicas_dead_fails_explicitly():
    svc = make_fleet(2)
    reqs = [DetectionRequest(uid=i, frame=_frame(seed=i)) for i in range(3)]
    for r in reqs:
        svc.submit(r)
    svc.kill_replica(0)
    svc.kill_replica(1)
    assert all(r.status is RequestStatus.FAILED for r in reqs)
    with pytest.raises(RuntimeError):
        svc.submit(DetectionRequest(uid=9, frame=_frame()))
    svc.close()


# --- bursty dropout storms (drive-cycle blackout frames) -----------------

def test_dropout_storm_coasts_through_blackout():
    cycle = make_drive_cycle(
        "straight", 18, 120, 160, seed=0,
        dropout_frames=(10, 11, 12),   # 3-frame camera blackout burst
    )
    clock = VirtualClock()
    svc = make_fleet(2, clock=clock)
    results = []
    for fr in cycle.frames:
        req = DetectionRequest(uid=fr.t, frame=fr.scene.image,
                               session_id="ego")
        svc.submit(req)
        svc.run()
        clock.advance(0.01)
        results.append((fr, req))
    assert all(r.is_terminal and r.served for _, r in results)
    # the stream stayed whole through the storm: one pinned tracker,
    # still holding a confirmed track after the blackout burst
    pin = svc.session_location("ego")
    holders = [rep.index for rep in svc.replicas
               if "ego" in rep.service.sessions]
    assert holders == [pin]
    assert any(t.confirmed for t in svc.session_tracks("ego"))
    svc.close()


# --- speculative local/remote offload ------------------------------------

def _spec_fleet(rtt_s: float, clock: VirtualClock) -> ShardedDetectionService:
    return make_fleet(
        2, clock=clock, remote_replica=1,
        speculative=SpeculativeConfig(rtt_s=rtt_s,
                                      local_shape=(96, 128)),
    )


def test_speculative_remote_upgrade_when_it_wins():
    clock = VirtualClock()
    svc = _spec_fleet(0.02, clock)
    req = DetectionRequest(uid=0, frame=_frame(), deadline_s=1.0)
    ticket = svc.submit_speculative(req)
    # local tier force-downshifted to the small bucket on replica 0
    assert ticket.local.bucket == (96, 128)
    assert ticket.remote.bucket == (120, 160)
    svc.replicas[0].service.run()       # local lands at t=0.00
    clock.advance(0.10)
    svc.replicas[1].service.run()       # remote computes at t=0.10
    decision = svc.resolve_speculative(ticket)
    assert decision is not None and decision.upgraded
    assert decision.winner == "remote"
    assert decision.local_met_deadline          # the guarantee held anyway
    # the caller's request carries the FULL-RES answer, stamped with the
    # modeled downlink: finished when the upgrade was in hand, not when
    # the remote replica computed it
    assert req.bucket == (120, 160) and req.downshift == 1
    assert req.finished_at == pytest.approx(0.10 + 0.02)
    assert svc.speculative_upgrades == 1
    svc.close()


def test_speculative_local_wins_when_network_too_slow():
    clock = VirtualClock()
    svc = _spec_fleet(0.5, clock)       # rtt alone blows the deadline
    req = DetectionRequest(uid=0, frame=_frame(), deadline_s=0.2)
    ticket = svc.submit_speculative(req)
    svc.replicas[0].service.run()
    clock.advance(0.05)
    svc.replicas[1].service.run()
    decision = svc.resolve_speculative(ticket)
    assert decision is not None and not decision.upgraded
    assert decision.winner == "local"
    assert decision.local_met_deadline
    # the low-res local answer stands: served inside the deadline
    assert req.bucket == (96, 128) and req.downshift > 1
    assert req.served and req.finished_at <= req.deadline_at
    assert svc.speculative_upgrades == 0
    svc.close()


def test_speculative_dead_remote_never_upgrades():
    clock = VirtualClock()
    svc = _spec_fleet(0.01, clock)
    svc.kill_replica(1)
    req = DetectionRequest(uid=0, frame=_frame(), deadline_s=1.0)
    ticket = svc.submit_speculative(req)
    assert ticket.remote.status is RequestStatus.FAILED
    svc.run()
    assert ticket.decision is not None and not ticket.decision.upgraded
    assert req.served and req.bucket == (96, 128)
    svc.close()


def test_speculative_race_is_deterministic():
    def arm():
        clock = VirtualClock()
        svc = _spec_fleet(0.02, clock)
        req = DetectionRequest(uid=0, frame=_frame(), deadline_s=0.5)
        ticket = svc.submit_speculative(req)
        svc.replicas[0].service.run()
        clock.advance(0.1)
        svc.replicas[1].service.run()
        d = svc.resolve_speculative(ticket)
        peaks = np.asarray(req.result.peaks)
        svc.close()
        return d, peaks

    d1, p1 = arm()
    d2, p2 = arm()
    assert d1 == d2
    np.testing.assert_array_equal(p1, p2)


# --- speculative race on the honest network ------------------------------

def _net_fleet(clock: VirtualClock, *, seed: int = 0, loss: float = 0.0,
               sigma: float = 0.0, rtt: float = 0.03,
               fraction: float = 0.5,
               race_timeout_s: float = None,
               faults: ServiceFaultInjector = None,
               n: int = 2, hosts: tuple = None) -> ShardedDetectionService:
    return make_fleet(
        n, clock=clock, remote_replica=n - 1, faults=faults, hosts=hosts,
        speculative=SpeculativeConfig(
            local_shape=(96, 128), race_timeout_s=race_timeout_s,
            network=NetworkConfig(seed=seed, rtt_median_s=rtt,
                                  uplink_fraction=fraction,
                                  jitter_sigma=sigma, loss=loss),
        ),
    )


def test_network_race_charges_the_uplink_before_remote_starts():
    clock = VirtualClock()
    svc = _net_fleet(clock)     # rtt 0.03, half per leg, no jitter/loss
    req = DetectionRequest(uid=0, frame=_frame(), deadline_s=0.1)
    ticket = svc.submit_speculative(req)
    # the remote clone is NOT in any queue yet: its request is on the wire
    assert not ticket.remote_submitted
    assert ticket.remote_submit_at == pytest.approx(0.015)
    svc.run()
    # the remote's submit stamp carries the uplink (the free-uplink fix),
    # and its deadline is the race's ORIGINAL absolute deadline
    assert ticket.remote.submitted_at == pytest.approx(0.015)
    assert ticket.remote.deadline_at == ticket.local.deadline_at
    d = ticket.decision
    assert d is not None and d.upgraded and not d.timed_out
    # in hand at uplink + compute(0 virtual) + downlink
    assert req.finished_at == pytest.approx(0.03)
    svc.close()


def test_network_race_decision_stream_is_deterministic():
    def arm():
        clock = VirtualClock()
        svc = _net_fleet(clock, seed=11, loss=0.2, sigma=0.6)
        for i in range(6):
            req = DetectionRequest(uid=i, frame=_frame(seed=i),
                                   deadline_s=0.1)
            svc.submit_speculative(req)
            svc.run()
        decisions = [t.decision for t in svc._tickets]
        svc.close()
        return decisions

    d1, d2 = arm(), arm()
    assert all(d is not None for d in d1)   # every race resolved
    assert d1 == d2                          # same seed -> same stream


def test_lost_uplink_remote_never_runs_local_still_answers():
    clock = VirtualClock()
    faults = ServiceFaultInjector(lose_uplink_races=(0,))
    svc = _net_fleet(clock, faults=faults)
    req = DetectionRequest(uid=0, frame=_frame(), deadline_s=0.1)
    ticket = svc.submit_speculative(req)
    svc.run()
    d = ticket.decision
    assert d is not None and d.timed_out and d.winner == "local"
    # the remote pass never ran: the request died on the wire
    assert not ticket.remote_submitted
    assert svc.replicas[1].service.dispatches == 0
    assert req.served and req.finished_at <= req.deadline_at
    assert req.bucket == (96, 128)
    assert svc.speculative_timeouts == 1
    assert svc.uplink_lost_total == 1
    svc.close()


def test_lost_downlink_computes_but_never_upgrades():
    clock = VirtualClock()
    faults = ServiceFaultInjector(lose_downlink_races=(0,))
    svc = _net_fleet(clock, faults=faults)
    req = DetectionRequest(uid=0, frame=_frame(), deadline_s=0.2)
    ticket = svc.submit_speculative(req)
    svc.run()
    d = ticket.decision
    assert d is not None and not d.upgraded and not d.timed_out
    # the remote DID compute — the answer just never came back
    assert ticket.remote_submitted and ticket.remote.ok
    assert svc.replicas[1].service.dispatches == 1
    assert req.bucket == (96, 128)
    assert svc.downlink_lost_total == 1
    assert svc.speculative_upgrades == 0
    svc.close()


def test_deadline_less_race_resolves_via_race_timeout():
    clock = VirtualClock()
    faults = ServiceFaultInjector(lose_uplink_races=(0,))
    svc = _net_fleet(clock, faults=faults, race_timeout_s=0.5)
    req = DetectionRequest(uid=0, frame=_frame())   # no deadline
    ticket = svc.submit_speculative(req)
    svc.run()
    d = ticket.decision
    assert d is not None and d.timed_out and d.winner == "local"
    assert clock() >= 0.5           # run() jumped to the timeout
    assert svc.speculative_timeouts == 1
    assert req.served
    svc.close()


def test_speculative_local_prefers_a_different_host_than_remote():
    svc = make_fleet(
        4, clock=VirtualClock(), hosts=(0, 0, 1, 1), remote_replica=3,
        speculative=SpeculativeConfig(local_shape=(96, 128)),
    )
    req = DetectionRequest(uid=0, frame=_frame(), deadline_s=1.0)
    svc.submit_speculative(req)
    # remote sits on host 1 (replica 3); the local guarantee must not
    # share its failure domain — replica 2 (host 1) takes nothing
    assert svc.replicas[2].service.queued == 0
    assert (svc.replicas[0].service.queued
            + svc.replicas[1].service.queued) == 1
    svc.run()
    assert req.served
    svc.close()


# --- elastic scale-up + host failure domains ------------------------------

def test_migrate_session_to_replica_dying_same_step():
    svc = make_fleet(3)
    for t in range(3):
        svc.submit(DetectionRequest(uid=t, frame=_frame(seed=0),
                                    session_id="ego"))
        svc.run()
    src = svc.session_location("ego")
    dst = (src + 1) % 3
    assert svc.migrate_session("ego", dst)
    svc.kill_replica(dst)   # the tracker just moved onto a corpse
    assert svc.session_location("ego") is None
    assert svc.session_failovers >= 1
    # the next frame re-pins on a survivor and rebuilds — nothing hangs
    req = DetectionRequest(uid=99, frame=_frame(seed=0), session_id="ego")
    svc.submit(req)
    svc.run()
    assert req.ok
    pin = svc.session_location("ego")
    assert pin is not None and pin != dst and svc.replicas[pin].alive
    holders = [rep.index for rep in svc.replicas
               if rep.alive and "ego" in rep.service.sessions]
    assert holders == [pin]
    svc.close()


def test_add_replica_rebalances_to_fair_share():
    svc = make_fleet(2)
    for s in range(6):
        for t in range(2):
            svc.submit(DetectionRequest(uid=s * 10 + t,
                                        frame=_frame(seed=s),
                                        session_id=f"s{s}"))
            svc.run()
    assert all(svc.session_location(f"s{s}") is not None for s in range(6))
    new = svc.add_replica()
    assert new == 2 and len(svc.replicas) == 3
    assert svc.scale_up_migrations > 0
    # the newcomer's estimator was warmed from a veteran, not cold
    for shape, g in svc.replicas[new].service.grids.items():
        assert g.est_s == svc.replicas[0].service.grids[shape].est_s
    counts: dict[int, int] = {}
    for s in range(6):
        sid = f"s{s}"
        pin = svc.session_location(sid)
        holders = [rep.index for rep in svc.replicas
                   if sid in rep.service.sessions]
        # one tracker per session, living exactly at the pin
        assert holders == [pin], (sid, holders, pin)
        counts[pin] = counts.get(pin, 0) + 1
    assert max(counts.values()) <= math.ceil(6 / 3)
    # migrated streams keep serving on their new replica
    for s in range(6):
        req = DetectionRequest(uid=100 + s, frame=_frame(seed=s),
                               session_id=f"s{s}")
        svc.submit(req)
        svc.run()
        assert req.ok
    assert svc.replicas[new].service.dispatches > 0
    svc.close()


def test_host_kill_takes_the_whole_group_survivors_absorb():
    clock = VirtualClock()
    svc = make_fleet(4, clock=clock, hosts=(0, 0, 1, 1), max_queue=16)
    reqs = [DetectionRequest(uid=i, frame=_frame(seed=i), deadline_s=5.0)
            for i in range(8)]
    for r in reqs:
        svc.submit(r)
    deadlines = [r.deadline_at for r in reqs]
    clock.advance(0.5)
    svc.kill_host(0)
    assert [rep.alive for rep in svc.replicas] == [False, False, True, True]
    assert svc.host_kills == 1
    # re-routed work kept its ORIGINAL absolute deadline
    for r, dl in zip(reqs, deadlines):
        assert r.deadline_at == dl
    assert svc.requeued > 0
    svc.run()
    assert all(r.is_terminal for r in reqs)
    # everything that wasn't caught in flight was served by host 1
    assert sum(r.ok for r in reqs) + svc.failed_on_death == len(reqs)
    assert (svc.replicas[2].service.dispatches
            + svc.replicas[3].service.dispatches) >= svc.requeued
    svc.close()


def test_host_kill_via_fault_schedule():
    faults = ServiceFaultInjector(kill_host_at=((1, 0),))
    svc = make_fleet(4, faults=faults, hosts=(0, 0, 1, 1))
    reqs = [DetectionRequest(uid=i, frame=_frame(seed=i)) for i in range(6)]
    for r in reqs:
        svc.submit(r)
    svc.run()
    assert not svc.replicas[0].alive and not svc.replicas[1].alive
    assert svc.replicas[2].alive and svc.replicas[3].alive
    assert all(r.is_terminal for r in reqs)
    assert sum(r.ok for r in reqs) + svc.failed_on_death == len(reqs)
    svc.close()


# --- real 8-device placement (subprocess, slow) --------------------------

@pytest.mark.slow
def test_eight_device_fleet_placement():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    body = textwrap.dedent("""
        import jax, numpy as np
        from repro.core.plan import HoughConfig, PipelineConfig
        from repro.data import make_scenario
        from repro.launch.mesh import make_replica_mesh, replica_devices
        from repro.serve.detection import DetectionRequest, VirtualClock
        from repro.serve.fleet import ShardedDetectionService
        from repro.sharding.partition import shard_slots

        assert len(jax.devices()) == 8, jax.devices()

        # slot-axis sharding: one slot grid spread over the replica mesh
        mesh = make_replica_mesh(8)
        batch = np.random.default_rng(0).random((8, 96, 128), np.float32)
        sharded = shard_slots(batch, mesh)
        shards = sharded.addressable_shards
        assert len(shards) == 8
        assert all(s.data.shape == (1, 96, 128) for s in shards)
        assert len({s.device for s in shards}) == 8
        np.testing.assert_array_equal(np.asarray(sharded), batch)

        # fleet: one replica per physical device, distinct plan caches
        cfg = PipelineConfig(hough=HoughConfig(compact=True,
                                               max_edges="auto"))
        svc = ShardedDetectionService(
            cfg, n_replicas=8, devices=replica_devices(8),
            clock=VirtualClock(), buckets=((96, 128), (120, 160)),
            batch_size=1, prefetch=False,
        )
        devs = {rep.service.device for rep in svc.replicas}
        assert len(devs) == 8
        frame = make_scenario("straight", 120, 160, seed=0).image
        reqs = [DetectionRequest(uid=i, frame=frame) for i in range(8)]
        for r in reqs:
            svc.submit(r)
        svc.run()
        assert all(r.ok for r in reqs)
        # every replica served one request, each on its own device
        assert [rep.service.dispatches for rep in svc.replicas] == [1] * 8
        ref = np.asarray(reqs[0].result.peaks)
        for r in reqs[1:]:
            np.testing.assert_array_equal(np.asarray(r.result.peaks), ref)
        svc.close()
        print("8-device fleet placement OK")
    """)
    r = subprocess.run([sys.executable, "-c", body], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
