"""Scenario-engine detection-quality regression tests.

The accuracy net every perf PR must pass: for each registered road-scene
family the detector must recover the planted lines within (drho <= 4 px,
dtheta <= 3 deg), hold the family's F1 floor, and do so identically across
the dense, compacted, and autotuned (``max_edges="auto"``) execution paths.
"""

import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CannyConfig, HoughConfig, LineDetector, PipelineConfig,
    aggregate_scores, auto_max_edges, canny, estimate_edge_count,
    score_batch, score_frame,
)
from repro.core.metrics import match_peaks, rho_theta_residual
from repro.data import (
    get_family, make_scenario, scenario_batch, scenario_names,
    scenario_stream, segment_rho_theta,
)

pytestmark = pytest.mark.scenarios

FAMILIES = scenario_names()

# The three execution paths the quality bar covers: dense voting, the
# compacted fast path (hand-tuned buffer), and the autotuned buffer.
VARIANTS = {
    "dense": HoughConfig(compact=False),
    "compact": HoughConfig(compact=True),
    "auto": HoughConfig(compact=True, max_edges="auto"),
}


def _detector(variant: str) -> LineDetector:
    return LineDetector(PipelineConfig(hough=VARIANTS[variant]))


# --- geometry / registry sanity -------------------------------------------


def test_registry_has_required_families():
    """The engine covers the scenario classes the ISSUE demands (>= 8)."""
    assert len(FAMILIES) >= 8
    for required in ("straight", "converging", "dashed", "curved", "night",
                     "glare", "rain", "occlusion", "multilane"):
        assert required in FAMILIES


def test_segment_rho_theta_roundtrip():
    """Planted normal forms satisfy x cos(t) + y sin(t) = rho at both
    endpoints, with theta canonicalized into [0, pi)."""
    rng = np.random.default_rng(0)
    for _ in range(50):
        x0, y0, x1, y1 = rng.uniform(-100, 400, 4)
        if abs(x1 - x0) + abs(y1 - y0) < 1e-3:
            continue
        rho, theta = segment_rho_theta(x0, y0, x1, y1)
        assert 0.0 <= theta < math.pi
        for x, y in ((x0, y0), (x1, y1)):
            assert abs(x * math.cos(theta) + y * math.sin(theta) - rho) < 1e-6


def test_scenarios_are_deterministic_and_distinct():
    for name in FAMILIES:
        a = make_scenario(name, 96, 128, seed=5)
        b = make_scenario(name, 96, 128, seed=5)
        np.testing.assert_array_equal(a.image, b.image)
        np.testing.assert_array_equal(a.lines_rho_theta, b.lines_rho_theta)
        c = make_scenario(name, 96, 128, seed=6)
        assert not np.array_equal(a.image, c.image)


# --- metric self-tests ------------------------------------------------------


def test_metrics_wraparound_identity():
    """(rho, theta) and (-rho, theta + pi) are the same line."""
    drho, dth = rho_theta_residual((50.0, 0.02), (-50.0, math.pi - 0.01))
    assert drho < 1e-6 and dth < 0.05


def test_metrics_matching_is_one_to_one():
    truth = np.array([[100.0, 1.0], [200.0, 2.0]])
    det = np.array([[101.0, 1.01], [100.5, 1.0], [300.0, 0.5]])
    matches = match_peaks(det, truth)
    assert len(matches) == 1  # only one detection may claim truth 0
    s = score_frame(det, np.ones(3, bool), truth)
    assert s.tp == 1 and s.fn == 1
    assert s.dup == 1   # the second near-duplicate of truth 0
    assert s.fp == 1    # the (300, 0.5) stray


def test_metrics_empty_cases():
    s = score_frame(np.zeros((0, 2)), np.zeros(0, bool), np.zeros((0, 2)))
    assert s.f1 == 1.0 and s.perfect
    s = score_frame(np.array([[1.0, 1.0]]), np.ones(1, bool),
                    np.zeros((0, 2)))
    assert s.fp == 1 and s.precision == 0.0


# --- the regression net -----------------------------------------------------


@pytest.mark.parametrize("variant", sorted(VARIANTS))
@pytest.mark.parametrize("name", FAMILIES)
def test_family_recovers_planted_lines(name, variant):
    """Strict per-line recovery at small resolution: every planted line is
    matched within (4 px, 3 deg) on each of 4 seeds, on every execution
    path (dense / compact / autotuned buffer)."""
    det = _detector(variant)
    for seed in range(4):
        sc = make_scenario(name, 120, 160, seed=seed)
        res = det.detect(jnp.asarray(sc.image, jnp.float32))
        s = score_frame(res.peaks, res.valid, sc.lines_rho_theta)
        assert s.fn == 0, (
            f"{name} seed {seed} [{variant}]: "
            f"{s.fn} of {len(sc.lines_rho_theta)} planted lines missed"
        )


@pytest.mark.parametrize("name", FAMILIES)
def test_family_f1_floor_batch(name):
    """Micro-averaged F1 over an 8-seed batch at 240x320 stays above the
    family's registered floor, with tight localization on the matches."""
    imgs, truths = scenario_batch([name] * 8, 240, 320, seed=0)
    det = _detector("compact")
    res = det.detect_batch(jnp.asarray(imgs))
    agg = aggregate_scores(score_batch(res.peaks, res.valid, truths))
    floor = get_family(name).f1_floor
    assert agg["f1"] >= floor, (name, agg)
    if agg["tp"]:
        assert agg["mean_rho_err"] <= 4.0
        assert agg["mean_theta_err_deg"] <= 3.0


def test_empty_scene_has_no_detections():
    """False-positive control: a markings-free frame yields zero valid
    peaks (the relative threshold is floored, not free-falling)."""
    det = _detector("dense")
    for seed in range(4):
        sc = make_scenario("empty", 240, 320, seed=seed)
        res = det.detect(jnp.asarray(sc.image, jnp.float32))
        assert int(np.asarray(res.valid).sum()) == 0


# --- autotuned max_edges ----------------------------------------------------


@pytest.mark.parametrize("name", FAMILIES)
def test_estimator_upper_bounds_edge_count(name):
    """The downsampled gradient estimate never under-sizes the buffer:
    estimate >= actual Canny edge count on every family and seed."""
    cfg = CannyConfig()
    for seed in range(4):
        sc = make_scenario(name, 120, 160, seed=seed)
        edges = canny(jnp.asarray(sc.image, jnp.float32), cfg)
        actual = int(np.asarray(edges >= 250).sum())
        est = estimate_edge_count(sc.image, cfg)
        assert est >= actual, (name, seed, est, actual)


def test_auto_never_exceeds_hand_tuned_buffer():
    """auto_max_edges caps at the dense-dispatch default (the hand-tuned
    buffer), and bucketing keeps nearby workloads on one jit key."""
    cap = max(256, (240 * 320) // 16)
    assert auto_max_edges(10 ** 9, 240, 320) == cap
    assert auto_max_edges(100, 240, 320) == 512
    assert auto_max_edges(513, 240, 320) == 1024
    for name in FAMILIES:
        det = _detector("auto")
        sc = make_scenario(name, 240, 320, seed=0)
        got = det.resolve_config(
            jnp.asarray(sc.image, jnp.float32)
        ).hough.max_edges
        assert isinstance(got, int) and 512 <= got <= cap, (name, got)


@pytest.mark.parametrize("name", ("converging", "rain", "multilane"))
def test_auto_bit_exact_with_dense(name):
    """Autotuning never drops a planted line: the auto-sized compacted
    pipeline's detections equal the dense path bit-for-bit."""
    sc = make_scenario(name, 240, 320, seed=1)
    img = jnp.asarray(sc.image, jnp.float32)
    rd = _detector("dense").detect(img)
    ra = _detector("auto").detect(img)
    np.testing.assert_array_equal(np.asarray(rd.lines), np.asarray(ra.lines))
    np.testing.assert_array_equal(np.asarray(rd.valid), np.asarray(ra.valid))
    np.testing.assert_array_equal(np.asarray(rd.peaks), np.asarray(ra.peaks))


def test_auto_on_heterogeneous_batch_sizes_for_densest_frame():
    """A mixed-family batch resolves ONE buffer >= every per-frame need,
    and the batched result matches the per-frame loop bit-exactly."""
    names = ["empty", "rain", "straight", "multilane"]
    imgs, _ = scenario_batch(names, 120, 160, seed=0)
    det = _detector("auto")
    batch_cfg = det.resolve_config(jnp.asarray(imgs))
    per_frame = [
        det.resolve_config(jnp.asarray(imgs[i])).hough.max_edges
        for i in range(len(names))
    ]
    assert batch_cfg.hough.max_edges == max(per_frame)
    rb = det.detect_batch(jnp.asarray(imgs))
    for i in range(len(names)):
        r = det.detect(jnp.asarray(imgs[i]))
        np.testing.assert_array_equal(np.asarray(rb.lines[i]),
                                      np.asarray(r.lines))
        np.testing.assert_array_equal(np.asarray(rb.valid[i]),
                                      np.asarray(r.valid))


def test_auto_works_under_jit_via_tiered_plan():
    """The plan layer resolves "auto" ON DEVICE (tiered lax.switch), so
    detect traces cleanly under an outer jit — the PR-2 behaviour (a
    ValueError demanding a concrete frame) is gone — and the traced result
    equals the eager path bit-for-bit."""
    import jax
    det = _detector("auto")
    sc = make_scenario("converging", 96, 128, seed=0)
    img = jnp.asarray(sc.image, jnp.float32)
    eager = det.detect(img)
    traced = jax.jit(det.detect)(img)
    np.testing.assert_array_equal(np.asarray(eager.lines),
                                  np.asarray(traced.lines))
    np.testing.assert_array_equal(np.asarray(eager.valid),
                                  np.asarray(traced.valid))
    # the legacy host-side resolver still demands a concrete frame
    with pytest.raises((ValueError, jax.errors.TracerArrayConversionError)):
        jax.jit(det.resolve_config)(jnp.zeros((32, 32), jnp.float32))


def test_auto_resolution_in_hough_transform():
    """hough_transform resolves "auto" from a concrete edge map via the
    exact edge count (no estimator needed post-Canny)."""
    from repro.core import hough_transform
    sc = make_scenario("converging", 120, 160, seed=0)
    edges = canny(jnp.asarray(sc.image, jnp.float32), CannyConfig())
    dense = hough_transform(edges, HoughConfig())
    auto = hough_transform(
        edges, HoughConfig(compact=True, max_edges="auto")
    )
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(auto))


# --- heterogeneous streaming ------------------------------------------------


@pytest.mark.parametrize("variant", ("compact", "auto"))
def test_mixed_scenario_stream_matches_per_frame(variant):
    """detect_stream over a rotating-family stream (uneven final batch)
    yields exactly the per-frame loop's results, in order."""
    frames = [s.image for s in scenario_stream("mixed", 5, 96, 128, seed=2)]
    det = _detector(variant)
    got = list(det.detect_stream(iter(frames), batch_size=2))
    assert len(got) == 5
    for f, r in zip(frames, got):
        ref = det.detect(jnp.asarray(f, jnp.float32))
        np.testing.assert_array_equal(np.asarray(r.lines),
                                      np.asarray(ref.lines))
        np.testing.assert_array_equal(np.asarray(r.valid),
                                      np.asarray(ref.valid))
