"""The HLO static cost analyzer vs hand-computed programs."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_cost import analyze


def _hlo(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_plain_matmul_flops_exact():
    M, K, N = 64, 96, 128
    c = analyze(_hlo(lambda a, b: a @ b, jnp.zeros((M, K)), jnp.zeros((K, N))))
    assert c.dot_flops == 2 * M * N * K
    # bytes at least the three arrays once
    assert c.bytes >= (M * K + K * N + M * N) * 4


def test_scan_trip_count_multiplies():
    def loss(ws, x):
        def body(x, w):
            return jnp.tanh(x @ w), None
        return jax.lax.scan(body, x, ws)[0]
    c = analyze(_hlo(loss, jnp.zeros((24, 64, 64)), jnp.zeros((8, 64))))
    assert c.dot_flops == 24 * 2 * 8 * 64 * 64


def test_nested_scan():
    def loss(ws, x):
        def outer(x, w):
            def inner(x2, _):
                return jnp.tanh(x2 @ w), None
            return jax.lax.scan(inner, x, None, length=7)[0], None
        return jax.lax.scan(outer, x, ws)[0]
    c = analyze(_hlo(loss, jnp.zeros((5, 32, 32)), jnp.zeros((4, 32))))
    assert c.dot_flops == 5 * 7 * 2 * 4 * 32 * 32


def test_grad_of_scan_counts_bwd():
    def loss(ws, x):
        def body(x, w):
            return jnp.tanh(x @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return jnp.sum(y)
    c = analyze(_hlo(jax.grad(loss), jnp.zeros((24, 64, 64)),
                     jnp.zeros((8, 64))))
    # fwd (1x) + bwd (2x) matmul flops
    assert c.dot_flops == 3 * 24 * 2 * 8 * 64 * 64


def test_scan_bytes_do_not_explode():
    """Per-iteration slice reads must not be charged as the full stack."""
    n, m = 100, 256

    def loss(ws, x):
        def body(x, w):
            return jnp.tanh(x @ w), None
        return jax.lax.scan(body, x, ws)[0]
    c = analyze(_hlo(loss, jnp.zeros((n, m, m)), jnp.zeros((4, m))))
    stack_bytes = n * m * m * 4
    # reading each layer slice once ~= one stack pass; allow small overhead,
    # but the n x overcount (n*stack) must not happen
    assert c.bytes < 4 * stack_bytes, (c.bytes, stack_bytes)
