"""Distributed behaviour on an 8-device host mesh (subprocess isolation).

Device count is locked at first jax init, so every multi-device scenario
runs in its own python subprocess with XLA_FLAGS set.  Each scenario script
asserts internally and exits nonzero on failure.
"""

import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow  # multi-device subprocesses, minutes each

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_script(body: str, timeout=900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(body)],
        env=env, capture_output=True, text=True, timeout=timeout,
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_sharded_train_step_matches_single_device():
    run_script("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import sharding
        from repro.configs import get_smoke, ShapeSpec
        from repro.models import build
        from repro.models.model_zoo import materialize_inputs, batch_axes, input_specs
        from repro.sharding import DEFAULT_RULES, shardings_for_tree
        from repro.train import AdamWConfig, make_train_step
        from repro.train.state import init_train_state, train_state_shardings
        from repro.launch.mesh import make_host_mesh

        cfg = get_smoke("yi-9b")
        m = build(cfg)
        rng = jax.random.PRNGKey(0)
        params = m.init(rng)
        batch = materialize_inputs(rng, cfg, ShapeSpec("t", 16, 8, "train"))
        opt = AdamWConfig(peak_lr=1e-3, warmup_steps=0, decay_steps=10)

        # single-device reference
        s_ref, met_ref = jax.jit(make_train_step(m, opt))(init_train_state(params), batch)

        mesh = make_host_mesh()   # (4, 2) or (2, 4) over 8 devices
        abs_state, st_sh = train_state_shardings(m, mesh)
        in_axes = batch_axes(cfg, "train")
        b_sh = shardings_for_tree(in_axes, input_specs(cfg, ShapeSpec("t", 16, 8, "train")), mesh)
        with sharding.activate(mesh, DEFAULT_RULES):
            step = jax.jit(make_train_step(m, opt), in_shardings=(st_sh, b_sh))
            state0 = jax.device_put(init_train_state(params), st_sh)
            batch_d = jax.device_put(batch, b_sh)
            s_sh, met_sh = step(state0, batch_d)
        np.testing.assert_allclose(float(met_ref["loss"]), float(met_sh["loss"]), rtol=1e-4)
        a = np.asarray(jax.device_get(s_sh.params["final_norm"]["w"]))
        b = np.asarray(s_ref.params["final_norm"]["w"])
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-5)
        print("sharded == single-device OK")
    """)


def test_elastic_checkpoint_resharding():
    """Save on a (4,2) mesh, restore onto (2,2) subset — mesh-agnostic files."""
    run_script("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from repro.checkpoint import save, restore
        from repro.sharding import DEFAULT_RULES, shardings_for_tree
        from repro.configs import get_smoke
        from repro.models import build

        cfg = get_smoke("yi-9b")
        m = build(cfg)
        params = m.init(jax.random.PRNGKey(0))

        mesh_a = jax.make_mesh((4, 2), ("data", "model"))
        sh_a = shardings_for_tree(m.param_axes(), m.abstract_params(), mesh_a)
        p_a = jax.device_put(params, sh_a)

        d = tempfile.mkdtemp()
        save(p_a, d, 1)

        # "elastic downsize": rebuild over 4 devices only
        import numpy as _np
        devs = _np.asarray(jax.devices()[:4]).reshape(2, 2)
        from jax.sharding import Mesh
        mesh_b = Mesh(devs, ("data", "model"))
        sh_b = shardings_for_tree(m.param_axes(), m.abstract_params(), mesh_b)
        p_b = restore(d, m.abstract_params(), shardings=sh_b)
        for x, y in zip(jax.tree.leaves(p_b), jax.tree.leaves(params)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        print("elastic restore OK")
    """)


@pytest.mark.skipif(
    not hasattr(__import__("jax"), "shard_map"),
    reason="partial-auto shard_map over a scanned model body aborts this "
           "XLA's SPMD partitioner (IsManualSubgroup check, uncatchable); "
           "needs the jax.shard_map era — see ROADMAP open items",
)
def test_pod_compressed_train_step():
    """int8 pod-compressed step runs on a (2,2,2) mesh and tracks the
    uncompressed step closely (error feedback)."""
    run_script("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import sharding
        from repro.configs import get_smoke, ShapeSpec
        from repro.models import build
        from repro.models.model_zoo import materialize_inputs
        from repro.train import AdamWConfig, make_train_step
        from repro.train.trainer import make_train_step_pod_compressed
        from repro.train.state import init_train_state

        cfg = get_smoke("yi-9b")
        m = build(cfg)
        rng = jax.random.PRNGKey(0)
        params = m.init(rng)
        batch = materialize_inputs(rng, cfg, ShapeSpec("t", 16, 8, "train"))
        opt = AdamWConfig(peak_lr=1e-3, warmup_steps=0, decay_steps=100)

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        with sharding.activate(mesh):
            comp = jax.jit(make_train_step_pod_compressed(m, opt, mesh))
            ref = jax.jit(make_train_step(m, opt))
            s_c = init_train_state(params, compression=True)
            s_r = init_train_state(params)
            for i in range(3):
                s_c, met_c = comp(s_c, batch)
                s_r, met_r = ref(s_r, batch)
        # same data => compressed trajectory tracks exact one
        np.testing.assert_allclose(float(met_c["loss"]), float(met_r["loss"]), rtol=2e-2)
        a = np.asarray(jax.device_get(s_c.params["final_norm"]["w"]))
        b = np.asarray(s_r.params["final_norm"]["w"])
        np.testing.assert_allclose(a, b, rtol=5e-2, atol=1e-4)
        print("pod-compressed OK; loss", float(met_c["loss"]), float(met_r["loss"]))
    """)


def test_compressed_allreduce_exactness():
    run_script("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.sharding import shard_map
        from repro.train.compression import compressed_allreduce

        mesh = jax.make_mesh((8,), ("pod",))
        x = jnp.arange(8 * 32, dtype=jnp.float32).reshape(8, 32) / 17.0
        err = jnp.zeros_like(x)

        def f(x, e):
            return compressed_allreduce(x[0], e[0], "pod")

        mean, new_err = jax.jit(shard_map(
            f, mesh=mesh, in_specs=(P("pod"), P("pod")),
            out_specs=(P(), P("pod")), check_vma=False,
        ))(x, err)
        want = np.asarray(x).mean(0)
        got = np.asarray(mean)
        tol = np.abs(np.asarray(x)).max() / 127.0
        assert np.abs(got - want).max() <= tol, (got, want)
        print("compressed allreduce OK")
    """)


def test_moe_ep_matches_reference():
    """Fully-manual 2D EP == single-device sort dispatch (ample capacity)."""
    run_script("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro import sharding
        from repro.configs import get_smoke, ShapeSpec
        from repro.models import build
        from repro.models.model_zoo import materialize_inputs
        from repro.launch.mesh import make_host_mesh

        cfg = get_smoke("moonshot-v1-16b-a3b")
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
        m = build(cfg)
        rng = jax.random.PRNGKey(0)
        params = m.init(rng)
        batch = materialize_inputs(rng, cfg, ShapeSpec("t", 16, 8, "train"))
        from repro.models import transformer
        ref, _ = transformer.forward(params, batch, cfg, moe_strategy="sort")

        mesh = make_host_mesh()   # (4, 2) data x model; experts 8 % 2 == 0
        with sharding.activate(mesh):
            got, _ = jax.jit(lambda p, b: transformer.forward(
                p, b, cfg, moe_strategy="ep"))(params, batch)
        # MoE routing is discontinuous: bf16 noise can flip a borderline
        # token's expert between paths, so compare in bulk (99th pct) plus
        # a loose max bound, not elementwise-tight.
        diff = np.abs(np.asarray(ref) - np.asarray(got))
        assert np.quantile(diff, 0.99) < 3e-2, np.quantile(diff, 0.99)
        assert diff.mean() < 5e-3, diff.mean()
        assert diff.max() < 1.0, diff.max()
        print("moe ep parity OK")
    """)


def test_sharded_decode_step():
    """Decode with cache sharded over a host mesh == single device decode."""
    run_script("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import sharding
        from repro.configs import get_smoke
        from repro.models import build
        from repro.sharding import DECODE_RULES, shardings_for_tree
        from repro.launch.mesh import make_host_mesh

        cfg = get_smoke("granite-34b")   # MQA kv=1: cache seq-sharding path
        m = build(cfg)
        params = m.init(jax.random.PRNGKey(0))
        B, L = 4, 32
        cache = m.init_cache(B, L)
        tok = jnp.asarray([1, 2, 3, 4], jnp.int32)
        pos = jnp.zeros((B,), jnp.int32)
        ref_logits, _ = m.decode_step(params, tok, cache, pos)

        mesh = make_host_mesh()
        c_abs, c_axes = m.cache_spec(B, L)
        c_sh = shardings_for_tree(c_axes, c_abs, mesh, DECODE_RULES)
        with sharding.activate(mesh, DECODE_RULES):
            cache_d = jax.device_put(m.init_cache(B, L), c_sh)
            logits, _ = jax.jit(m.decode_step)(params, tok, cache_d, pos)
        np.testing.assert_allclose(np.asarray(ref_logits), np.asarray(logits), rtol=2e-2, atol=2e-2)
        print("sharded decode OK")
    """)
