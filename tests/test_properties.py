"""Property-based tests (hypothesis) on system invariants."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import HoughConfig, hough_transform, quantize, dequantize
from repro.core.canny import GAUSS_5x5, SOBEL_X
from repro.kernels import ref

SETTINGS = dict(max_examples=20, deadline=None)


@settings(**SETTINGS)
@given(
    st.integers(1, 64).map(lambda n: n * 4),
    st.floats(0.1, 100.0),
    st.integers(0, 2 ** 31 - 1),
)
def test_quantize_roundtrip_bound(n, scale, seed):
    """|x - deq(q(x))| <= amax/127 elementwise, any scale."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n,)) * scale, jnp.float32)
    q = quantize(x)
    err = jnp.abs(dequantize(q) - x).max()
    bound = jnp.abs(x).max() / 127.0
    assert float(err) <= float(bound) * 1.001 + 1e-9


@settings(**SETTINGS)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 6))
def test_hough_vote_conservation(seed, density):
    """Total votes == n_edge_pixels * n_theta (each edge pixel votes once
    per angle; rho always lands in range by construction)."""
    rng = np.random.default_rng(seed)
    H, W = 24, 32
    img = (rng.uniform(size=(H, W)) < density / 10.0) * 255.0
    cfg = HoughConfig(n_theta=60)
    votes = hough_transform(jnp.asarray(img, jnp.float32), cfg)
    n_edge = int((img >= cfg.edge_threshold).sum())
    assert abs(float(votes.sum()) - n_edge * cfg.n_theta) <= max(n_edge, 1)


@settings(**SETTINGS)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 9),
       st.sampled_from([16, 64, 96]))
def test_compact_edges_is_prefix_of_edge_indices(seed, density, max_edges):
    """compact_edges is a *stable* compaction: its output is exactly the
    first ``max_edges`` edge rows in original index order (no permutation,
    no fabrication), zero-padded past the edge count — for both the
    prefix-sum-scatter kernel and the argsort oracle."""
    from repro.kernels.hough_vote import compact_edges as compact_kernel

    rng = np.random.default_rng(seed)
    n_pix = 128
    w = (rng.uniform(size=n_pix) < density / 10.0).astype(np.float32)
    xy = np.stack([np.arange(n_pix), np.arange(n_pix) * 2,
                   np.ones(n_pix)], axis=1).astype(np.float32)
    idx = np.flatnonzero(w > 0)[:max_edges]
    want_xy = np.zeros((max_edges, 3), np.float32)
    want_w = np.zeros(max_edges, np.float32)
    want_xy[: len(idx)] = xy[idx]
    want_w[: len(idx)] = w[idx]
    for impl in (compact_kernel, ref.compact_edges):
        cxy, cw = impl(jnp.asarray(xy), jnp.asarray(w), max_edges=max_edges)
        np.testing.assert_array_equal(np.asarray(cxy), want_xy)
        np.testing.assert_array_equal(np.asarray(cw), want_w)


@settings(**SETTINGS)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 6))
def test_compacted_vote_bit_exact_when_buffer_fits(seed, density):
    """Whenever n_edges <= max_edges, the compacted accumulator equals the
    dense one bit-for-bit (vote sums are small integers, exact in f32)."""
    from repro.kernels import ops

    rng = np.random.default_rng(seed)
    H, W = 24, 32
    img = (rng.uniform(size=(H, W)) < density / 20.0) * 255.0
    cfg = HoughConfig(n_theta=45)
    n_edges = int((img >= cfg.edge_threshold).sum())
    max_edges = max(8, n_edges)  # buffer always fits

    diag = math.hypot(H, W)
    theta = np.arange(cfg.n_theta) * (math.pi / cfg.n_theta)
    trig = np.stack([np.cos(theta), np.sin(theta),
                     np.full_like(theta, diag)]).astype(np.float32)
    jj, ii = np.meshgrid(np.arange(W), np.arange(H))
    xy = np.stack([jj.ravel(), ii.ravel(), np.ones(H * W)],
                  axis=1).astype(np.float32)
    weights = (img.ravel() >= cfg.edge_threshold).astype(np.float32)
    n_rho = int(2 * diag) + 1

    dense = ops.hough_vote(jnp.asarray(xy), jnp.asarray(weights),
                           jnp.asarray(trig), n_rho=n_rho, impl="xla")
    compact = ops.hough_vote(jnp.asarray(xy), jnp.asarray(weights),
                             jnp.asarray(trig), n_rho=n_rho, impl="xla",
                             compact=True, max_edges=max_edges)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(compact))


@settings(**SETTINGS)
@given(st.integers(0, 2 ** 31 - 1))
def test_conv_linearity(seed):
    """conv(a*x + b*y) == a*conv(x) + b*conv(y) (it IS a GEMM)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(16, 20)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(16, 20)), jnp.float32)
    masks = jnp.asarray(np.stack([GAUSS_5x5 / 159.0,
                                  np.pad(SOBEL_X, 1)]), jnp.float32)
    a, b = 2.5, -1.25
    lhs = ref.conv2d_gemm(a * x + b * y, masks)
    rhs = a * ref.conv2d_gemm(x, masks) + b * ref.conv2d_gemm(y, masks)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(st.integers(0, 2 ** 31 - 1), st.integers(8, 40))
def test_attention_causal_prefix_property(seed, L):
    """Causal attention output at position t depends only on tokens <= t:
    truncating the suffix must not change the prefix outputs."""
    rng = np.random.default_rng(seed)
    B, H, D = 1, 2, 8
    q = jnp.asarray(rng.normal(size=(B, H, L, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, H, L, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, H, L, D)), jnp.float32)
    cut = L // 2
    full = ref.attention(q, k, v, causal=True)
    part = ref.attention(q[:, :, :cut], k[:, :, :cut], v[:, :, :cut],
                         causal=True)
    np.testing.assert_allclose(np.asarray(full[:, :, :cut]),
                               np.asarray(part), rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(st.integers(0, 2 ** 31 - 1))
def test_attention_permutation_equivariance_batch(seed):
    """Permuting the batch permutes outputs (no cross-request leakage) —
    the invariant continuous batching relies on."""
    rng = np.random.default_rng(seed)
    B, H, L, D = 4, 2, 12, 8
    q = jnp.asarray(rng.normal(size=(B, H, L, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, H, L, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, H, L, D)), jnp.float32)
    perm = np.asarray(rng.permutation(B))
    out = ref.attention(q, k, v, causal=True)
    out_p = ref.attention(q[perm], k[perm], v[perm], causal=True)
    np.testing.assert_allclose(np.asarray(out[perm]), np.asarray(out_p),
                               rtol=1e-6, atol=1e-6)


@settings(**SETTINGS)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([16, 24, 32]))
def test_ssd_matches_sequential_property(seed, L):
    rng = np.random.default_rng(seed)
    B, H, P, N, G = 1, 2, 8, 4, 1
    x = jnp.asarray(rng.normal(size=(B, L, H, P)) * 0.2, jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, L, H)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.2, 1.5, (H,)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, L, G, N)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(B, L, G, N)), jnp.float32)
    yc, hc = ref.ssd_scan_chunked(x, dt, A, Bm, C, chunk=8)
    ys, hs = ref.ssd_scan(x, dt, A, Bm, C)
    np.testing.assert_allclose(np.asarray(yc), np.asarray(ys),
                               rtol=3e-3, atol=3e-3)


@settings(**SETTINGS)
@given(st.integers(0, 2 ** 31 - 1))
def test_data_pipeline_determinism_property(seed):
    from repro.data import TokenPipelineConfig, TokenStream
    cfg = TokenPipelineConfig(vocab=64, seq_len=16, global_batch=4,
                              seed=seed % 1000)
    a = TokenStream(cfg).batch_at(seed % 50)
    b = TokenStream(cfg).batch_at(seed % 50)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
