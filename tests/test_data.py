"""Data pipeline: determinism, sharding, prefetch, straggler mitigation."""

import time

import numpy as np
import pytest

from repro.data import (
    PrefetchLoader, SkipAheadLoader, TokenPipelineConfig, TokenStream,
)


def _cfg(**kw):
    base = dict(vocab=256, seq_len=32, global_batch=8, seed=7)
    base.update(kw)
    return TokenPipelineConfig(**base)


def test_step_indexed_determinism():
    s1 = TokenStream(_cfg())
    s2 = TokenStream(_cfg())
    for step in (0, 5, 1000):
        a, b = s1.batch_at(step), s2.batch_at(step)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        np.testing.assert_array_equal(a["targets"], b["targets"])
    # different steps differ
    assert not np.array_equal(s1.batch_at(0)["tokens"],
                              s1.batch_at(1)["tokens"])


def test_targets_are_shifted_tokens():
    b = TokenStream(_cfg()).batch_at(0)
    # target[t] is the next token of an underlying (S+1) stream:
    # tokens[:, 1:] == targets[:, :-1]
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])


def test_shards_partition_global_batch():
    full = TokenStream(_cfg(n_shards=1, shard=0)).batch_at(3)["tokens"]
    parts = [
        TokenStream(_cfg(n_shards=4, shard=s)).batch_at(3)["tokens"]
        for s in range(4)
    ]
    np.testing.assert_array_equal(np.concatenate(parts, 0), full)


def test_prefetch_ordering():
    loader = PrefetchLoader(TokenStream(_cfg()), depth=2, start_step=5)
    try:
        steps = [loader.get()[0] for _ in range(4)]
        assert steps == [5, 6, 7, 8]
    finally:
        loader.close()


def test_skip_ahead_straggler():
    """A producer that stalls on one step gets skipped; cadence holds."""
    delays = {2: 0.6}
    loader = SkipAheadLoader(
        TokenStream(_cfg()), timeout_s=0.25,
        delay_fn=lambda step: delays.get(step, 0.0),
    )
    got = [loader.get()[0] for _ in range(4)]
    assert got == [0, 1, 3, 4]          # step 2 sacrificed
    assert loader.skipped == [2]


def test_skip_ahead_bounded():
    loader = SkipAheadLoader(
        TokenStream(_cfg()), timeout_s=0.05, max_consecutive_skips=2,
        delay_fn=lambda step: 1.0,       # permanently stalled
    )
    with pytest.raises(RuntimeError, match="stalled"):
        for _ in range(5):
            loader.get()


def test_resume_from_step():
    """start_step resumes the exact stream (restart determinism)."""
    s = TokenStream(_cfg())
    fresh = [s.batch_at(i)["tokens"] for i in range(6)]
    loader = PrefetchLoader(s, start_step=3)
    try:
        for i in (3, 4, 5):
            step, batch = loader.get()
            assert step == i
            np.testing.assert_array_equal(batch["tokens"], fresh[i])
    finally:
        loader.close()
