"""Degradation ladder + fault-injection harness (fleet robustness).

Every test drives ``DetectionService`` on a :class:`VirtualClock` — the
ladder decisions (downshift / coast / shed), the injected faults (stager
death, dispatch failure, stalls, clock jumps, corrupt frames), and the
SLO accounting are all pure functions of the driven schedule.  The
contract under test is the robustness contract of ``ISSUE``-grade
overload: every request reaches an *explicit* terminal status (no
hangs), coast answers run zero detection dispatches, and degraded
answers stay in native coordinates.
"""

import dataclasses
import math

import numpy as np
import pytest

from repro.core import HoughConfig, PipelineConfig
from repro.core.plan import DetectionResult, downsample2x, downshift_frame
from repro.core.tracking import LaneTracker, TrackerConfig
from repro.runtime import HeartbeatMonitor, ServiceFaultInjector, WorkerFailure
from repro.serve.detection import (
    SHED_ONLY, DegradationPolicy, DetectionRequest, DetectionService,
    PrefetchStager, RequestStatus, VirtualClock, upscale_result,
)

pytestmark = pytest.mark.fleet

BUCKETS = ((96, 128), (120, 160))


def _cfg() -> PipelineConfig:
    return PipelineConfig(hough=HoughConfig(compact=True, max_edges="auto"))


def make_svc(**kw) -> DetectionService:
    kw.setdefault("buckets", BUCKETS)
    kw.setdefault("batch_size", 1)
    kw.setdefault("clock", VirtualClock())
    kw.setdefault("prefetch", False)
    return DetectionService(_cfg(), **kw)


def _frame(h: int, w: int, seed: int = 0) -> np.ndarray:
    from repro.data import make_scenario
    return make_scenario("straight", h, w, seed=seed).image


def _ground_estimate(svc, clock, shape, dt, uid0=900):
    """Measure the bucket's EMA at ``dt`` via warm no-deadline traffic."""
    warms = [DetectionRequest(uid=uid0 + u, frame=_frame(*shape, seed=u))
             for u in range(3)]
    for w in warms:
        svc.submit(w)
        svc.step()
        clock.advance(dt)
    svc.drain()
    assert all(w.ok for w in warms)
    assert svc.grids[shape].est_measured


def _warm_session(svc, sid, n=8, shape=(96, 128), uid0=800):
    """Feed ``n`` real frames so the session's tracker earns the coast
    (confirmed + ``hits >= coast_hits`` under the default config)."""
    for i in range(n):
        r = DetectionRequest(uid=uid0 + i, frame=_frame(*shape),
                             session_id=sid)
        svc.submit(r)
        svc.run()
        assert r.ok and r.tracks
    assert svc.sessions[sid].can_coast()


# --- status classification (satellite: is_terminal routing) -----------------


def test_status_classification_single_source():
    """Every status classifies through RequestStatus properties, and the
    terminal set partitions exactly into served vs refused."""
    for s in RequestStatus:
        if s is RequestStatus.PENDING:
            assert not s.terminal and not s.served and not s.refused
        else:
            assert s.terminal
            assert s.served != s.refused   # exact partition
    r = DetectionRequest(uid=0, frame=np.zeros((96, 128), np.float32))
    assert not r.is_terminal and not r.done
    r.status = RequestStatus.DEGRADED_COAST
    assert r.is_terminal and r.done           # done is the alias
    assert r.served and r.degraded and not r.ok
    r.status = RequestStatus.FAILED
    assert r.is_terminal and not r.served and r.status.refused


# --- virtual clock edge cases (satellite) -----------------------------------


def test_virtual_clock_rejects_backward_jump():
    clock = VirtualClock()
    clock.advance(2.0)
    assert clock.jump_to(5.0) == 5.0
    assert clock.jump_to(5.0) == 5.0          # zero-width jump is fine
    with pytest.raises(ValueError):
        clock.jump_to(4.0)
    with pytest.raises(AssertionError):
        clock.advance(-0.1)
    assert clock() == 5.0                      # rejected jumps change nothing


def test_forward_jump_expires_whole_edf_wave_in_one_step():
    """One large jump past every queued deadline: a single step() sheds
    the entire wave — no per-entry stepping, no hang."""
    clock = VirtualClock()
    svc = make_svc(buckets=((96, 128),), clock=clock)
    reqs = [DetectionRequest(uid=i, frame=_frame(96, 128, seed=i),
                             deadline_s=float(1 + i))
            for i in range(4)]
    for r in reqs:
        svc.submit(r)
    clock.jump_to(100.0)
    svc.step()
    assert all(r.status is RequestStatus.DEADLINE_EXCEEDED for r in reqs)
    assert svc.shed_deadline == 4 and svc.dispatches == 0


def test_zero_duration_dispatch_does_not_poison_ema():
    """Back-to-back dispatches with no clock motion (dt == 0) must leave
    the EMA unmeasured — a zero estimate would make every deadline look
    feasible forever."""
    svc = make_svc(buckets=((96, 128),))
    svc.detect_many([_frame(96, 128, seed=s) for s in range(4)])
    g = svc.grids[(96, 128)]
    assert not g.est_measured and g.est_s > 0.0   # prior intact


# --- downshift rung ---------------------------------------------------------


def test_downsample2x_and_downshift_frame_shapes():
    img = np.arange(120 * 160, dtype=np.float32).reshape(120, 160)
    half = downsample2x(img)
    assert half.shape == (60, 80) and half.dtype == np.float32
    # 2x2 mean of the top-left block
    assert half[0, 0] == pytest.approx(img[:2, :2].mean())
    odd = downsample2x(np.ones((5, 7), np.float32))
    assert odd.shape == (3, 4) and np.allclose(odd, 1.0)  # edge-replicated
    out, factor = downshift_frame(img, (96, 128))
    assert factor == 2 and out.shape == (60, 80)
    same, factor1 = downshift_frame(img, (120, 160))
    assert factor1 == 1 and same.shape == (120, 160)


def test_upscale_result_maps_coordinates_exactly():
    """The pool chain maps native centers x -> (x - c)/factor with
    c = (factor-1)/2; upscale_result must apply the exact inverse."""
    peaks = np.array([[18.25, 0.0], [10.0, math.pi / 2]], np.float32)
    lines = np.array([[18.25, 0.0, 18.25, 59.0]], np.float32)
    res = DetectionResult(
        lines, np.array([1], np.int32), peaks,
        np.zeros((60, 80), np.float32), None,
    )
    up = upscale_result(res, 2, 120, 160)
    # vertical line (theta=0): rho' = 2*18.25 + 0.5*(cos0 + sin0) = 37.0
    assert up.peaks[0, 0] == pytest.approx(37.0)
    assert up.peaks[0, 1] == pytest.approx(0.0)
    # horizontal line (theta=pi/2): same offset math on the y axis
    assert up.peaks[1, 0] == pytest.approx(2 * 10.0 + 0.5)
    np.testing.assert_allclose(up.lines, 2.0 * lines + 0.5)
    assert up.edges.shape == (120, 160)


def test_ladder_downshifts_instead_of_shedding():
    """A deadline hopeless at the native bucket but feasible one bucket
    down is served DEGRADED_DOWNSHIFT from the smaller grid, in native
    coordinates and close to the full-fidelity answer; the identical
    traffic with the ladder off is shed."""
    frame = _frame(120, 160)
    full = make_svc().detect_many([frame])[0]

    clock = VirtualClock()
    svc = make_svc(clock=clock)
    _ground_estimate(svc, clock, (120, 160), dt=0.2)
    req = DetectionRequest(uid=0, frame=frame, deadline_s=0.05)
    svc.submit(req)
    svc.run()
    assert req.status is RequestStatus.DEGRADED_DOWNSHIFT
    assert req.served and req.degraded and not req.ok and req.done
    assert req.downshift == 2 and req.bucket == (96, 128)
    assert svc.downshifted == 1 and svc.served_downshift == 1
    assert svc.dispatch_log[-1][0] == (96, 128)
    # native-coordinate answer: the strongest peak agrees with the
    # full-fidelity run to within the pooled quantization
    assert req.result.edges.shape == (120, 160)
    pa = np.asarray(req.result.peaks)[0]
    pb = np.asarray(full.result.peaks)[0]
    assert abs(pa[0] - pb[0]) < 6.0 and abs(pa[1] - pb[1]) < 0.12

    clock2 = VirtualClock()
    off = make_svc(clock=clock2, ladder=False)
    _ground_estimate(off, clock2, (120, 160), dt=0.2)
    req2 = DetectionRequest(uid=0, frame=frame, deadline_s=0.05)
    off.submit(req2)
    off.run()
    assert req2.status is RequestStatus.DEADLINE_EXCEEDED


def test_downshift_respects_policy_and_floor():
    """allow_downshift=False and a floor above every smaller bucket both
    exhaust the rung; with no session to coast on, the request sheds."""
    for policy in (SHED_ONLY,
                   DegradationPolicy(floor=(120, 160))):
        clock = VirtualClock()
        svc = make_svc(clock=clock)
        _ground_estimate(svc, clock, (120, 160), dt=0.2)
        req = DetectionRequest(uid=0, frame=_frame(120, 160),
                               deadline_s=0.05, policy=policy)
        svc.submit(req)
        svc.run()
        assert req.status is RequestStatus.DEADLINE_EXCEEDED
        assert svc.downshifted == 0 and svc.served_coast == 0


# --- coast rung -------------------------------------------------------------


def test_coast_rung_serves_from_tracker_with_zero_dispatches():
    """An overloaded session request is answered from the tracker's
    prediction: DEGRADED_COAST, no Hough dispatch, non-mutating."""
    clock = VirtualClock()
    svc = make_svc(buckets=((96, 128),), clock=clock)
    _warm_session(svc, "cam0")
    _ground_estimate(svc, clock, (96, 128), dt=0.05)
    before = svc.dispatches
    tracker_state = [dataclasses.replace(t)
                     for t in svc.sessions["cam0"]._tracks]
    req = DetectionRequest(uid=0, frame=_frame(96, 128),
                           session_id="cam0", deadline_s=0.02)
    svc.submit(req)
    svc.run()
    assert req.status is RequestStatus.DEGRADED_COAST
    assert req.tracks and req.result is None
    assert svc.dispatches == before            # ZERO detection dispatches
    assert svc.served_coast == 1 and svc.shed_deadline == 0
    # the tracker itself did not advance (the coast is a pure prediction)
    for t0, t1 in zip(tracker_state, svc.sessions["cam0"]._tracks):
        assert t0.rho == t1.rho and t0.misses == t1.misses
    slo = svc.session_slo("cam0")
    assert slo.served_coast == 1 and slo.served_full == 8
    assert slo.miss_rate == 0.0 and slo.degraded_rate == pytest.approx(1 / 9)


def test_coast_budget_exhausts_like_a_real_dropout():
    """Consecutive coasts burn the tracker's miss budget (max_misses);
    past it the rung refuses until a real frame re-grounds the session."""
    clock = VirtualClock()
    svc = make_svc(buckets=((96, 128),), clock=clock)
    _warm_session(svc, "cam0")
    _ground_estimate(svc, clock, (96, 128), dt=0.05)
    budget = svc.tracker_cfg.max_misses
    coasted = []
    for i in range(budget + 1):
        r = DetectionRequest(uid=10 + i, frame=_frame(96, 128),
                             session_id="cam0", deadline_s=0.02)
        svc.submit(r)
        svc.run()
        coasted.append(r.status)
    assert coasted[:budget] == [RequestStatus.DEGRADED_COAST] * budget
    assert coasted[budget] is RequestStatus.DEADLINE_EXCEEDED
    # a real frame resets the coast budget
    real = DetectionRequest(uid=50, frame=_frame(96, 128),
                            session_id="cam0")
    svc.submit(real)
    svc.run()
    assert real.ok
    again = DetectionRequest(uid=51, frame=_frame(96, 128),
                             session_id="cam0", deadline_s=0.02)
    svc.submit(again)
    svc.run()
    assert again.status is RequestStatus.DEGRADED_COAST


def test_coast_respects_policy():
    clock = VirtualClock()
    svc = make_svc(buckets=((96, 128),), clock=clock)
    _warm_session(svc, "cam0")
    _ground_estimate(svc, clock, (96, 128), dt=0.05)
    req = DetectionRequest(uid=0, frame=_frame(96, 128),
                           session_id="cam0", deadline_s=0.02,
                           policy=DegradationPolicy(allow_coast=False))
    svc.submit(req)
    svc.run()
    assert req.status is RequestStatus.DEADLINE_EXCEEDED


# --- priority-tiered shedding (last rung) -----------------------------------


def test_eviction_displaces_strictly_lower_tier_only():
    svc = make_svc(buckets=((96, 128),), max_queue=1)
    lo = DetectionRequest(uid=0, frame=_frame(96, 128), priority=2)
    svc.submit(lo)
    hi = DetectionRequest(uid=1, frame=_frame(96, 128, seed=1), priority=0)
    assert svc.submit(hi) is RequestStatus.PENDING   # displaced the tier-2
    assert lo.status is RequestStatus.QUEUE_FULL and svc.evicted == 1
    peer = DetectionRequest(uid=2, frame=_frame(96, 128, seed=2), priority=0)
    assert svc.submit(peer) is RequestStatus.QUEUE_FULL  # no lower tier left
    assert svc.evicted == 1 and svc.rejected_queue_full == 2
    svc.run()
    assert hi.ok


def test_no_eviction_with_ladder_off():
    svc = make_svc(buckets=((96, 128),), max_queue=1, ladder=False)
    lo = DetectionRequest(uid=0, frame=_frame(96, 128), priority=2)
    svc.submit(lo)
    hi = DetectionRequest(uid=1, frame=_frame(96, 128, seed=1), priority=0)
    assert svc.submit(hi) is RequestStatus.QUEUE_FULL   # old contract
    assert lo.status is RequestStatus.PENDING and svc.evicted == 0
    svc.run()
    assert lo.ok


# --- prefetch-worker death (satellite: explicit error, never a hang) --------


def test_stager_death_mid_stream_surfaces_explicitly():
    """Kill the worker thread mid-stream: the fatal task's future and
    every queued future resolve with WorkerFailure, and later stage()
    calls raise immediately — no caller can block forever."""
    calls = []

    def hook():
        calls.append(1)
        if len(calls) == 2:
            raise WorkerFailure("injected death")

    st = PrefetchStager(fault_hook=hook)
    try:
        f1 = st.stage(lambda x: x + 1, 1)
        assert f1.result(timeout=10.0) == 2
        futs = []
        try:
            for i in range(4):       # one of these is fatal
                futs.append(st.stage(lambda x: x, i))
        except WorkerFailure:
            pass                     # raised at the submit site: also fine
        st._thread.join(timeout=10.0)
        assert not st.alive
        for f in futs:               # every accepted future RESOLVES
            with pytest.raises(WorkerFailure):
                f.result(timeout=10.0)
        with pytest.raises(WorkerFailure):
            st.stage(lambda: 0)
    finally:
        st.close()


def test_stager_heartbeat_on_virtual_clock():
    clock = VirtualClock()
    reg: dict = {}
    st = PrefetchStager(heartbeat_registry=reg, clock=clock, worker_id="w0")
    try:
        assert st.stage(lambda: 42).result(timeout=10.0) == 42
        mon = HeartbeatMonitor(reg, timeout_s=1.0, clock=clock)
        assert mon.all_alive()
        clock.advance(5.0)          # silence past the liveness deadline
        assert "w0" in mon.dead_workers()
    finally:
        st.close()


def test_service_restarts_dead_stager_and_still_answers():
    """An injected stager death inside the service path costs overlap,
    never correctness: the service restarts the worker (new heartbeat
    incarnation) and every request completes DONE."""
    faults = ServiceFaultInjector(kill_stager_at=(0,))
    svc = make_svc(buckets=((96, 128),), prefetch=True, faults=faults)
    frames = [np.repeat(_frame(96, 128, seed=s)[..., None], 3, axis=2)
              for s in range(4)]    # RGB: staging does real work
    reqs = [DetectionRequest(uid=i, frame=f) for i, f in enumerate(frames)]
    for r in reqs:
        svc.submit(r)
    svc.run()
    svc.close()
    assert all(r.ok for r in reqs)
    assert svc.stager_deaths == 1
    assert "detection-prefetch-0" in svc.heartbeats


def test_stager_restart_budget_falls_back_to_synchronous():
    faults = ServiceFaultInjector(kill_stager_at=(0, 1, 2, 3, 4, 5))
    svc = make_svc(buckets=((96, 128),), prefetch=True, faults=faults,
                   max_stager_restarts=1)
    frames = [np.repeat(_frame(96, 128, seed=s)[..., None], 3, axis=2)
              for s in range(6)]
    reqs = [DetectionRequest(uid=i, frame=f) for i, f in enumerate(frames)]
    for r in reqs:
        svc.submit(r)
        svc.run()                   # interleave so each death is observed
    svc.close()
    assert all(r.ok for r in reqs)  # synchronous fallback, same answers
    assert not svc.prefetch         # budget spent: prefetch disabled
    assert svc.stager_deaths == 2   # 1 restart + the one that broke it


# --- dispatch faults, stalls, corrupt frames, clock jumps -------------------


def test_injected_dispatch_failure_is_explicit_and_isolated():
    faults = ServiceFaultInjector(fail_dispatch_at=(0,))
    svc = make_svc(buckets=((96, 128),), faults=faults)
    a = DetectionRequest(uid=0, frame=_frame(96, 128))
    b = DetectionRequest(uid=1, frame=_frame(96, 128, seed=1))
    svc.submit(a)
    svc.submit(b)
    svc.run()
    assert a.status is RequestStatus.FAILED and a.result is None
    assert b.ok                      # the fault does not leak forward
    assert svc.dispatch_faults == 1 and svc.completed == 1
    assert all(len(e) == 3 for e in svc.dispatch_log)


def test_injected_stall_lands_late_but_never_poisons_the_ema():
    clock = VirtualClock()
    faults = ServiceFaultInjector(stall_dispatch_at=(1,), stall_s=1.0)
    svc = make_svc(buckets=((96, 128),), clock=clock, faults=faults)
    w = DetectionRequest(uid=0, frame=_frame(96, 128))     # dispatch 0: cold
    svc.submit(w)
    svc.run()
    stalled = DetectionRequest(uid=1, frame=_frame(96, 128, seed=1),
                               deadline_s=0.5)             # dispatch 1: stall
    svc.submit(stalled)
    svc.run()
    assert stalled.ok and stalled.missed_deadline          # served, late
    assert stalled.finished_at == pytest.approx(1.0)
    assert svc.completed_late == 1
    assert not svc.grids[(96, 128)].est_measured   # stall sample excluded


def test_corrupt_frame_refuses_or_coasts():
    # no session to fall back on: explicit INVALID_FRAME
    faults = ServiceFaultInjector(corrupt_frame_uids=(0,))
    svc = make_svc(buckets=((96, 128),), faults=faults)
    bad = DetectionRequest(uid=0, frame=_frame(96, 128))
    ok = DetectionRequest(uid=1, frame=_frame(96, 128, seed=1))
    svc.submit(bad)
    svc.submit(ok)
    svc.run()
    assert bad.status is RequestStatus.INVALID_FRAME and bad.result is None
    assert ok.ok and svc.rejected_invalid == 1

    # a warmed session coasts through the bad capture instead
    clock = VirtualClock()
    faults2 = ServiceFaultInjector(corrupt_frame_uids=(0,))
    svc2 = make_svc(buckets=((96, 128),), clock=clock, faults=faults2)
    _warm_session(svc2, "cam0")
    req = DetectionRequest(uid=0, frame=_frame(96, 128), session_id="cam0")
    svc2.submit(req)
    svc2.run()
    assert req.status is RequestStatus.DEGRADED_COAST and req.tracks


def test_injected_clock_jump_expires_the_wave():
    clock = VirtualClock()
    faults = ServiceFaultInjector(clock_jump_at_step=(0,), clock_jump_s=50.0)
    svc = make_svc(buckets=((96, 128),), clock=clock, faults=faults)
    reqs = [DetectionRequest(uid=i, frame=_frame(96, 128, seed=i),
                             deadline_s=float(1 + i)) for i in range(3)]
    for r in reqs:
        svc.submit(r)
    svc.run()
    assert all(r.status is RequestStatus.DEADLINE_EXCEEDED for r in reqs)
    assert clock() >= 50.0 and svc.dispatches == 0


def test_every_fault_class_resolves_terminal():
    """The headline robustness contract: under a combined fault storm
    every submitted request reaches an explicit terminal status."""
    clock = VirtualClock()
    faults = ServiceFaultInjector(
        kill_stager_at=(1,), fail_dispatch_at=(2,),
        stall_dispatch_at=(4,), corrupt_frame_uids=(3, 7),
        clock_jump_at_step=(6,), clock_jump_s=0.5,
    )
    svc = make_svc(buckets=((96, 128),), prefetch=True, clock=clock,
                   faults=faults)
    reqs = []
    for i in range(12):
        f = _frame(96, 128, seed=i % 3)
        if i % 2:
            f = np.repeat(f[..., None], 3, axis=2)   # exercise staging
        reqs.append(DetectionRequest(
            uid=i, frame=f,
            deadline_s=2.0 if i % 3 == 0 else None,
        ))
    for r in reqs:
        svc.submit(r)
    svc.run()
    svc.close()
    assert all(r.is_terminal for r in reqs)          # no hangs, ever
    for r in reqs:
        assert r.served != r.status.refused          # exact partition
        assert (r.result is not None) == (
            r.status in (RequestStatus.DONE, RequestStatus.DEGRADED_DOWNSHIFT)
        )


# --- tracker coast-prediction unit ------------------------------------------


def test_predict_tracks_matches_real_coast_and_does_not_mutate():
    cfg = TrackerConfig()
    tr = LaneTracker(cfg)
    peaks = np.array([[40.0, 0.3], [90.0, 1.2]], np.float32)
    for k in range(cfg.coast_hits + 1):
        tr.step(peaks + np.float32(k) * np.array([[0.5, 0.0]] * 2,
                                                 np.float32))
    assert tr.can_coast()
    before = [dataclasses.replace(t) for t in tr._tracks]
    k = 2
    predicted = tr.predict_tracks(k)
    # non-mutating
    for t0, t1 in zip(before, tr._tracks):
        assert t0.rho == t1.rho and t0.drho == t1.drho
        assert t0.misses == t1.misses and t0.age == t1.age
    # bit-identical to actually coasting k empty frames
    twin = LaneTracker(cfg)
    for k2 in range(cfg.coast_hits + 1):
        twin.step(peaks + np.float32(k2) * np.array([[0.5, 0.0]] * 2,
                                                    np.float32))
    coasted = None
    for _ in range(k):
        coasted = twin.step(np.zeros((0, 2), np.float32))
    assert len(predicted) == len(coasted)
    for p, c in zip(sorted(predicted, key=lambda t: t.track_id),
                    sorted(coasted, key=lambda t: t.track_id)):
        assert p.rho == pytest.approx(c.rho)
        assert p.theta == pytest.approx(c.theta)
        assert p.misses == c.misses
    # beyond the miss budget the coast refuses
    assert tr.predict_tracks(cfg.max_misses + 1) == []


# --- union theta-band gated dispatch (PR 7) ---------------------------------


def _stream_cycle(svc, clock, *, session="ego", n=14, uid0=0):
    """Drive one session through a drive cycle, one frame per dispatch."""
    from repro.data import make_drive_cycle
    cycle = make_drive_cycle("straight", n, 120, 160, seed=0)
    reqs = []
    for fr in cycle.frames:
        req = DetectionRequest(uid=uid0 + fr.t, frame=fr.scene.image,
                               session_id=session)
        svc.submit(req)
        svc.run()
        clock.advance(0.01)
        reqs.append(req)
    return reqs


def test_union_gate_bitexact_with_full_sweep():
    """At full coverage the gated dispatch is bit-exact with the full
    sweep — the gate is a speedup, never a correctness dependence."""
    clock_g, clock_f = VirtualClock(), VirtualClock()
    gated = make_svc(clock=clock_g)                  # gate_band=40 default
    full = make_svc(clock=clock_f, gate_band=None)
    got = _stream_cycle(gated, clock_g)
    ref = _stream_cycle(full, clock_f)
    # the session confirms within a few frames; after that every
    # single-slot grid is fully covered and the gate engages
    assert gated.gated_dispatches > 0
    assert full.gated_dispatches == 0
    for g, f in zip(got, ref):
        assert g.ok and f.ok
        np.testing.assert_array_equal(np.asarray(g.result.peaks),
                                      np.asarray(f.result.peaks))
        np.testing.assert_array_equal(np.asarray(g.result.lines),
                                      np.asarray(f.result.lines))
        np.testing.assert_array_equal(np.asarray(g.result.valid),
                                      np.asarray(f.result.valid))
    gated.close()
    full.close()


def test_union_gate_requires_every_slot_covered():
    """A grid with any sessionless (or tracker-less) slot full-sweeps:
    gating is all-or-nothing per dispatch."""
    clock = VirtualClock()
    svc = make_svc(clock=clock, batch_size=2)
    # warm the session's tracker to gating health on single-slot grids
    for t in range(6):
        svc.submit(DetectionRequest(uid=t, frame=_frame(120, 160, seed=0),
                                    session_id="ego"))
        svc.submit(DetectionRequest(uid=100 + t,
                                    frame=_frame(120, 160, seed=0),
                                    session_id="ego"))
        svc.run()
        clock.advance(0.01)
    assert svc.sessions["ego"].gate_bins(svc.cfg.hough.n_theta) is not None
    before = svc.gated_dispatches
    # mixed grid: one session slot + one sessionless slot -> full sweep
    a = DetectionRequest(uid=200, frame=_frame(120, 160, seed=0),
                         session_id="ego")
    b = DetectionRequest(uid=201, frame=_frame(120, 160, seed=1))
    svc.submit(a)
    svc.submit(b)
    svc.run()
    assert a.ok and b.ok
    assert svc.gated_dispatches == before
    svc.close()


def test_union_gate_engages_on_covered_multisession_grid():
    clock = VirtualClock()
    svc = make_svc(clock=clock, batch_size=2)
    for t in range(6):
        for s, base in (("a", 0), ("b", 0)):
            svc.submit(DetectionRequest(
                uid=t * 10 + base + (0 if s == "a" else 1),
                frame=_frame(120, 160, seed=0), session_id=s))
        svc.run()
        clock.advance(0.01)
    assert svc.gated_dispatches > 0
    svc.close()


# --- coast starvation fix: warm-start + downshift persistence (PR 7) --------


def test_warm_start_coastable_fallback_semantics():
    """``coastable_tracks`` falls back to confirmed-but-young tracks only
    for a session that has EVER been grounded ``warm_frames`` times; the
    strict per-track bar still wins whenever it is met."""
    cfg = TrackerConfig()
    peaks = np.array([[40.0, 0.3]], np.float32)
    # cold tracker: confirmed but young track, no grounding history
    cold = LaneTracker(cfg)
    for _ in range(cfg.confirm_hits + 1):
        cold.step(peaks)
    assert cold.grounded_frames < cfg.warm_frames
    young = cold._tracks[0]
    assert young.confirmed and young.hits < cfg.coast_hits
    assert cold.coastable_tracks(1) == []            # starved, correctly
    # warm tracker: same young track state, but the SESSION is grounded
    warm = LaneTracker(cfg)
    for _ in range(cfg.warm_frames + 1):   # birth frame doesn't ground
        warm.step(peaks)
    assert warm.grounded_frames >= cfg.warm_frames
    warm._tracks[0].hits = cfg.coast_hits - 1        # re-born young track
    assert warm.coastable_tracks(1) != []            # warm start engages
    # strict bar preferred when any track meets it
    warm._tracks[0].hits = cfg.coast_hits
    assert [t.hits for t in warm.coastable_tracks(1)] == [cfg.coast_hits]


def test_tracker_step_scale_widens_rho_gate():
    """A downshifted frame's peaks carry ~factor x the rho quantization;
    ``step(scale=factor)`` widens the match gate so the track stays
    grounded instead of forking a twin."""
    cfg = TrackerConfig()
    tr = LaneTracker(cfg)
    peaks = np.array([[40.0, 0.3]], np.float32)
    for _ in range(3):
        tr.step(peaks)
    off = np.array([[40.0 + cfg.gate_rho * 1.5, 0.3]], np.float32)
    twin = LaneTracker(cfg)
    for _ in range(3):
        twin.step(peaks)
    tr.step(off)                     # native scale: outside the gate
    twin.step(off, scale=2.0)        # downshifted: gate widened 2x
    assert len(tr._tracks) == 2      # forked a twin track
    assert len(twin._tracks) == 1    # stayed grounded
    assert twin._tracks[0].hits == 4


def test_downshifted_stream_still_earns_coast():
    """The starvation fix end-to-end: a session served ONLY downshifted
    frames still accrues warm-start grounding, so a blackout frame gets a
    coast answer instead of a refusal."""
    clock = VirtualClock()
    svc = make_svc(clock=clock, validate_frames=True)
    cfg = svc.tracker_cfg
    for t in range(cfg.warm_frames + 2):
        req = DetectionRequest(uid=t, frame=_frame(120, 160, seed=0),
                               session_id="ego")
        svc.submit(req, force_bucket=(96, 128))
        svc.run()
        clock.advance(0.01)
        assert req.status is RequestStatus.DEGRADED_DOWNSHIFT
    tracker = svc.sessions["ego"]
    assert tracker.grounded_frames >= cfg.warm_frames
    assert tracker.coastable_tracks(1) != []
    bad = DetectionRequest(uid=99,
                           frame=np.full((120, 160), np.nan, np.float32),
                           session_id="ego")
    svc.submit(bad)
    svc.run()
    assert bad.status is RequestStatus.DEGRADED_COAST
    assert svc.slo["ego"].served_coast == 1
    svc.close()


# --- pre-downshift at admission (PR 7) --------------------------------------


def test_pre_downshift_engages_at_admission():
    """When the native bucket's measured backlog already makes the
    deadline infeasible at SUBMIT time, rung 1 fires immediately —
    the request never burns slack queueing at the doomed bucket."""
    clock = VirtualClock()
    svc = make_svc(clock=clock)
    _ground_estimate(svc, clock, (120, 160), 0.30, uid0=900)
    _ground_estimate(svc, clock, (96, 128), 0.01, uid0=910)
    # a wave ahead of us at the big bucket: deadline 0.15 < est 0.30
    blocker = DetectionRequest(uid=0, frame=_frame(120, 160, seed=0))
    svc.submit(blocker)
    req = DetectionRequest(uid=1, frame=_frame(120, 160, seed=1),
                           deadline_s=0.15)
    svc.submit(req)
    # downgraded at admission, before any scheduler step ran
    assert svc.pre_downshifted == 1
    assert req.bucket == (96, 128) and req.downshift == 2
    svc.run()
    assert req.status is RequestStatus.DEGRADED_DOWNSHIFT
    assert req.finished_at <= req.deadline_at
    svc.close()


def test_pre_downshift_skipped_when_feasible():
    clock = VirtualClock()
    svc = make_svc(clock=clock)
    _ground_estimate(svc, clock, (120, 160), 0.01, uid0=900)
    req = DetectionRequest(uid=0, frame=_frame(120, 160, seed=0),
                           deadline_s=1.0)
    svc.submit(req)
    assert svc.pre_downshifted == 0
    assert req.bucket == (120, 160)
    svc.run()
    assert req.ok
    svc.close()
