"""Perception-to-control tests: bird's-eye geometry, waypoint/pure-pursuit
control, the closed-loop drive harness, and steering on the service.

Layered like the stack:

  * geometry: homography round trips (image -> ground -> image exact to
    float precision), horizon guards, resolution rescaling;
  * transform_rho_theta: the PR-10 wrap bugfix (theta in [0, pi) for ANY
    yaw) and the compose-vs-one-shot invariant the closed-loop truth
    bookkeeping relies on (hypothesis property where available, seeded
    deterministic twin always);
  * dy threading: make_drive_cycle's surge leg recovers truth;
  * control: centerline extraction on analytic truth, fallback ladder,
    hold decay, pure-pursuit signs;
  * closed loop: convergence with working detection, divergence when
    blind, bit-reproducibility;
  * service: steering attached on served/coast/refused session requests.

Detector-in-the-loop tests run at the harness resolution 240x320 (the
camera model's native frame); everything else is pure host math.
"""
from __future__ import annotations

import math

import numpy as np
import pytest

from repro.core import (
    CameraConfig, CameraGeometry, ControlConfig, LateralController,
    canonical_rho_theta, extract_waypoints, ground_boundaries,
)
from repro.core.hough import HoughConfig
from repro.core.pipeline import LineDetector, PipelineConfig
from repro.core.tracking import TrackingPipeline
from repro.data import (
    ClosedLoopConfig, ClosedLoopCycle, make_drive_cycle, make_scenario,
    standard_closed_loop, transform_rho_theta,
)
from repro.serve.detection import (
    DetectionRequest, DetectionService, RequestStatus, VirtualClock,
)

pytestmark = pytest.mark.drive

HW = (240, 320)


def _cfg() -> PipelineConfig:
    return PipelineConfig(hough=HoughConfig(compact=True,
                                            max_edges="auto"))


# --- geometry ---------------------------------------------------------------


def test_canonical_rho_theta_all_wraps():
    rho, theta = 40.0, 0.7
    for k in range(-6, 7):
        r, t = canonical_rho_theta(rho if k % 2 == 0 else rho,
                                   theta + k * math.pi)
        assert 0.0 <= t < math.pi
        assert t == pytest.approx(theta, abs=1e-9)
        assert r == pytest.approx(rho if k % 2 == 0 else -rho, abs=1e-9)


def test_pixel_ground_round_trip():
    geo = CameraGeometry(CameraConfig())
    rng = np.random.default_rng(0)
    for _ in range(50):
        u = rng.uniform(0, 319)
        v = rng.uniform(geo.horizon_v + 5.0, 239)
        X, Y = geo.pixel_to_ground(u, v)
        assert Y > 0.0
        u2, v2 = geo.ground_to_pixel(X, Y)
        assert (u2, v2) == pytest.approx((u, v), abs=1e-8)


def test_ground_depth_increases_toward_horizon():
    geo = CameraGeometry(CameraConfig())
    ys = [geo.pixel_to_ground(159.5, v)[1] for v in (239, 180, 120, 60)]
    assert ys == sorted(ys)
    assert ys[0] < 2.5          # image bottom: a couple meters ahead
    assert ys[-1] > 10.0        # near the horizon: far field


def test_above_horizon_raises():
    geo = CameraGeometry(CameraConfig())
    with pytest.raises(ValueError):
        geo.pixel_to_ground(160.0, geo.horizon_v - 1.0)


def test_line_round_trip_image_ground_image():
    """image -> ground -> image is the identity to float precision (the
    homography maps lines exactly; no rasterization in this path)."""
    geo = CameraGeometry(CameraConfig())
    rng = np.random.default_rng(1)
    n = 0
    for _ in range(80):
        theta = rng.uniform(0.0, math.pi)
        rho = rng.uniform(-200.0, 200.0)
        try:
            rg, tg = geo.line_to_ground(rho, theta)
        except ValueError:
            continue        # the horizon line itself
        r2, t2 = geo.line_to_image(rg, tg)
        assert t2 == pytest.approx(theta, abs=1e-8)
        assert r2 == pytest.approx(rho, abs=1e-6)
        n += 1
    assert n > 70


def test_line_round_trip_ground_image_ground():
    geo = CameraGeometry(CameraConfig())
    rng = np.random.default_rng(2)
    for _ in range(40):
        tg = rng.uniform(0.0, math.pi)
        rg = rng.uniform(-3.0, 3.0)
        try:
            ri, ti = geo.line_to_image(rg, tg)
        except ValueError:
            continue
        rg2, tg2 = geo.line_to_ground(ri, ti)
        assert tg2 == pytest.approx(tg, abs=1e-8)
        assert rg2 == pytest.approx(rg, abs=1e-8)


def test_vertical_center_line_maps_to_centerline():
    """The image's vertical center line is the ground's X=0 axis."""
    geo = CameraGeometry(CameraConfig())
    cx = (320 - 1) / 2.0
    rg, tg = geo.line_to_ground(cx, 0.0)    # x = cx in the image
    # ground line X*cos(tg) + Y*sin(tg) = rg with X=0 for all Y
    assert rg == pytest.approx(0.0, abs=1e-9)
    assert tg == pytest.approx(0.0, abs=1e-9)


def test_camera_for_image_rescales():
    base = CameraConfig()
    half = base.for_image(120, 160)
    assert half.focal_px == pytest.approx(base.focal_px / 2.0)
    geo_b, geo_h = CameraGeometry(base), CameraGeometry(half)
    # the same physical ray: pixel (u, v) at full res is (u/2, v/2) at
    # half res, and both see the same ground point
    Xb, Yb = geo_b.pixel_to_ground(200.0, 200.0)
    Xh, Yh = geo_h.pixel_to_ground(100.0, 100.0)
    assert (Xh, Yh) == pytest.approx((Xb, Yb), abs=1e-6)
    assert base.for_image(240, 320) is base


def test_lines_to_ground_respects_valid_mask():
    geo = CameraGeometry(CameraConfig())
    peaks = np.array([[150.0, 0.1], [120.0, 0.2], [80.0, 2.9]], float)
    all_g = geo.lines_to_ground(peaks)
    masked = geo.lines_to_ground(peaks, [True, False, True])
    assert all_g.shape == (3, 2)
    assert masked.shape == (2, 2)
    assert np.allclose(masked, all_g[[0, 2]])


# --- transform_rho_theta: wrap bugfix + composition invariant ---------------


def test_transform_theta_canonical_for_large_yaw():
    """Regression (PR 10): the old single +-pi correction returned
    theta=3.358 for yaw=3.5 — outside [0, pi).  Any accumulated yaw must
    canonicalize."""
    for yaw in (3.5, -3.5, 7.2, -9.9, 2.0 * math.pi, 11.0,
                math.pi, -math.pi, 100.0):
        rp, tp = transform_rho_theta(30.0, 0.5, yaw_rad=yaw, dx=3.0,
                                     dy=-2.0, cx=159.5, cy=119.5)
        assert 0.0 <= tp < math.pi, f"yaw={yaw}: theta'={tp}"


def test_transform_wrap_parity_flips_rho():
    """A full pi of extra yaw is the same line with the normal flipped:
    theta' identical, rho' negated."""
    r1, t1 = transform_rho_theta(40.0, 0.8, yaw_rad=0.3, dx=0.0, dy=0.0,
                                 cx=80.0, cy=60.0)
    # same rotation composed with a half turn about the same center: the
    # frame's lines coincide (a line is invariant under point-reflection
    # through any of its... not its own points — but rho/theta quotient:
    # rotating the *normal* by pi flips its sign)
    r2, t2 = transform_rho_theta(-40.0, 0.8 + math.pi, yaw_rad=0.3,
                                 dx=0.0, dy=0.0, cx=80.0, cy=60.0)
    assert t2 == pytest.approx(t1, abs=1e-9)
    assert r2 == pytest.approx(r1, abs=1e-9)


def _compose_poses(poses):
    """Accumulate rigid center-rotations q = R(p - c) + c + t: yaw adds,
    translation composes as t_acc' = R2 t_acc + t2."""
    yaw_acc, tx, ty = 0.0, 0.0, 0.0
    for yaw, dx, dy in poses:
        c, s = math.cos(yaw), math.sin(yaw)
        tx, ty = c * tx - s * ty + dx, s * tx + c * ty + dy
        yaw_acc += yaw
    return yaw_acc, tx, ty


def _check_compose(poses, rho, theta, cx, cy, tol=1e-6):
    r_step, t_step = rho, theta
    for yaw, dx, dy in poses:
        r_step, t_step = transform_rho_theta(
            r_step, t_step, yaw_rad=yaw, dx=dx, dy=dy, cx=cx, cy=cy)
    yaw_acc, tx, ty = _compose_poses(poses)
    r_one, t_one = transform_rho_theta(rho, theta, yaw_rad=yaw_acc,
                                       dx=tx, dy=ty, cx=cx, cy=cy)
    # compare in the (rho, theta) ~ (-rho, theta+pi) quotient: float
    # rounding can land the canonical theta on either side of the seam
    dt = abs(t_step - t_one)
    if dt > math.pi / 2.0:
        dt = abs(dt - math.pi)
        r_one = -r_one
    assert dt <= tol, (t_step, t_one)
    assert abs(r_step - r_one) <= max(tol, tol * abs(r_step)), \
        (r_step, r_one)


def test_transform_composition_matches_one_shot_seeded():
    """Deterministic twin of the hypothesis property below: stepping a
    line through k incremental poses equals one transform of the
    accumulated pose — the invariant ClosedLoopCycle's truth relies on
    (it carries the ABSOLUTE pose and transforms once per frame)."""
    rng = np.random.default_rng(7)
    for _ in range(200):
        k = int(rng.integers(1, 8))
        poses = [(float(rng.uniform(-1.2, 1.2)),
                  float(rng.uniform(-30, 30)),
                  float(rng.uniform(-30, 30))) for _ in range(k)]
        rho = float(rng.uniform(-150, 150))
        theta = float(rng.uniform(0, math.pi))
        _check_compose(poses, rho, theta, cx=159.5, cy=119.5, tol=1e-6)


def test_transform_composition_matches_one_shot_hypothesis():
    """Property form over the pose space (skips w/o hypothesis)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    finite = dict(allow_nan=False, allow_infinity=False)

    @settings(max_examples=150, deadline=None)
    @given(
        poses=st.lists(
            st.tuples(st.floats(-1.5, 1.5, **finite),
                      st.floats(-40.0, 40.0, **finite),
                      st.floats(-40.0, 40.0, **finite)),
            min_size=1, max_size=8),
        rho=st.floats(-150.0, 150.0, **finite),
        theta=st.floats(0.0, math.pi - 1e-9, **finite),
    )
    def prop(poses, rho, theta):
        _check_compose(poses, rho, theta, cx=159.5, cy=119.5, tol=1e-5)

    prop()


# --- dy threading through make_drive_cycle ----------------------------------


def test_drive_cycle_surge_moves_frames_and_truth_follows():
    """The dy leg end to end: a surge-only cycle produces per-frame
    images that differ, and every planted stroke pixel lies on the
    transformed analytic truth — same invariant as the sway test in
    test_tracking.py, now for longitudinal motion."""
    cyc = make_drive_cycle("straight", 8, 120, 160, seed=1,
                           sway_px=0.0, surge_px=7.0, surge_period=9.0,
                           yaw_amp_deg=0.0)
    assert len({f.scene.image.tobytes() for f in cyc}) > 1
    saw_nonzero = False
    for f in cyc:
        if abs(f.dy_px) > 0.5:
            saw_nonzero = True
        ys, xs = np.nonzero(f.scene.image >= 230)
        assert len(xs) > 50
        dists = []
        for rho, theta in f.scene.lines_rho_theta:
            dists.append(np.abs(xs * math.cos(theta)
                                + ys * math.sin(theta) - rho))
        assert np.min(dists, axis=0).max() <= 3.0
    assert saw_nonzero


def test_drive_cycle_surge_truth_recovered_by_detector():
    """The detector finds the surged truth: dy threads through warp and
    truth consistently enough to score (localization within the
    matcher's gate)."""
    from repro.core.metrics import score_frame
    det = LineDetector(_cfg())
    cyc = make_drive_cycle("straight", 6, *HW, seed=0, sway_px=3.0,
                           surge_px=6.0, surge_period=7.0)
    for f in cyc:
        res = det.detect(f.scene.image)
        s = score_frame(np.asarray(res.peaks), np.asarray(res.valid),
                        f.scene.lines_rho_theta)
        # every surged line is found where the transform says it is
        # (precision can dip on duplicate raster peaks — recall and
        # localization are what prove the dy leg's truth)
        assert s.recall == 1.0
        assert s.mean_rho_err <= 2.0
        assert s.mean_theta_err_deg <= 2.0


def test_drive_cycle_default_has_no_surge():
    a = make_drive_cycle("straight", 4, 120, 160, seed=3)
    b = make_drive_cycle("straight", 4, 120, 160, seed=3, surge_px=0.0)
    for fa, fb in zip(a, b):
        assert fa.scene.image.tobytes() == fb.scene.image.tobytes()
        assert fa.dy_px == 0.0


# --- control: centerline, fallbacks, pure pursuit ---------------------------


def _truth_peaks(family="straight", seed=0):
    return make_scenario(family, *HW, seed=seed).lines_rho_theta


def test_ground_boundaries_filters_cross_traffic():
    geo = CameraGeometry(CameraConfig())
    cfg = ControlConfig()
    lanes = _truth_peaks()
    # a horizontal image line (theta ~ pi/2) is a stop line / horizon
    # artifact, not a lane boundary
    peaks = np.vstack([lanes, [[200.0, math.pi / 2.0]]])
    bounds = ground_boundaries(peaks, None, geo, cfg)
    assert len(bounds) == 2


def test_extract_waypoints_pair_centered_on_truth():
    geo = CameraGeometry(CameraConfig())
    wps = extract_waypoints(_truth_peaks(), None, geo, ControlConfig())
    assert wps.source == "pair"
    assert wps.points.shape == (5, 2)
    # the straight family's lanes are symmetric about the image center:
    # the centerline runs up the middle
    assert abs(wps.offset_m) < 0.05
    assert abs(wps.slope) < 0.05
    # waypoints ordered by increasing forward distance
    assert np.all(np.diff(wps.points[:, 1]) > 0)


def test_extract_waypoints_single_boundary_fallback():
    geo = CameraGeometry(CameraConfig())
    cfg = ControlConfig()
    lanes = _truth_peaks()
    left_only = extract_waypoints(lanes[:1], None, geo, cfg)
    right_only = extract_waypoints(lanes[1:], None, geo, cfg)
    assert {left_only.source, right_only.source} == {"left", "right"}
    none = extract_waypoints(np.zeros((0, 2)), None, geo, cfg)
    assert none.source == "none" and not none.found


def test_controller_steers_toward_center():
    """Perceived offset right of center -> negative curvature (turn
    left), and vice versa: the pure-pursuit sign that closes the loop."""
    geo = CameraGeometry(CameraConfig())
    H, W = HW
    cx, cy = (W - 1) / 2.0, (H - 1) / 2.0
    lanes = _truth_peaks()
    for dx_img, want in ((-30.0, -1.0), (30.0, +1.0)):
        # scene shifted right (dx>0) = vehicle left of center = steer
        # right (positive curvature)
        ctl = LateralController(geo, clock=lambda: 0.0)
        shifted = np.array([
            transform_rho_theta(float(r), float(t), yaw_rad=0.0,
                                dx=dx_img, dy=0.0, cx=cx, cy=cy)
            for r, t in lanes], np.float32)
        cmd = ctl.command(shifted)
        assert cmd.fresh and cmd.source == "pair"
        assert math.copysign(1.0, cmd.curvature) == want
        assert cmd.steer_rad == pytest.approx(
            math.atan(ctl.cfg.wheelbase_m * cmd.curvature))


def test_controller_single_boundary_uses_pair_memory():
    """After one full pair, a single visible boundary reconstructs the
    same centerline the pair gave (no half-width-prior jump)."""
    geo = CameraGeometry(CameraConfig())
    lanes = _truth_peaks()
    ctl = LateralController(geo, clock=lambda: 0.0)
    full = ctl.command(lanes)
    only_left = ctl.command(lanes[:1])
    assert only_left.source == "left"
    assert only_left.cross_track_m == pytest.approx(full.cross_track_m,
                                                    abs=1e-6)
    assert only_left.heading_rad == pytest.approx(full.heading_rad,
                                                  abs=1e-6)
    # stateless extraction (no memory) lands elsewhere: the memory is
    # doing real work
    stateless = extract_waypoints(lanes[:1], None, geo, ctl.cfg)
    assert abs(-stateless.offset_m - full.cross_track_m) > 0.01


def test_controller_hold_decays_to_straight():
    geo = CameraGeometry(CameraConfig())
    ctl = LateralController(geo, clock=lambda: 0.0)
    first = ctl.command(_truth_peaks())
    k0 = first.curvature
    ks = []
    for i in range(ctl.cfg.hold_frames + 3):
        cmd = ctl.hold()
        ks.append(cmd.curvature)
        if i < ctl.cfg.hold_frames:
            assert cmd.source == "hold" and cmd.age == i + 1
    assert ks[0] == pytest.approx(k0 * ctl.cfg.hold_decay)
    assert ks[-1] == 0.0            # budget spent: command straight
    assert ctl.hold().source == "none"
    # empty detections route through hold, not a crash
    ctl2 = LateralController(geo, clock=lambda: 0.0)
    assert ctl2.command(np.zeros((0, 2))).source == "none"


def test_controller_accepts_tracks():
    """Track objects (anything with .rho/.theta) are valid input — the
    tracked pipeline and the service coast path feed tracks directly."""
    from repro.core.tracking import Track
    geo = CameraGeometry(CameraConfig())
    lanes = _truth_peaks()
    tracks = [Track(track_id=i, rho=float(r), theta=float(t))
              for i, (r, t) in enumerate(lanes)]
    a = LateralController(geo, clock=lambda: 0.0).command(lanes)
    b = LateralController(geo, clock=lambda: 0.0).command(tracks)
    assert b.curvature == pytest.approx(a.curvature, abs=1e-6)


# --- closed loop ------------------------------------------------------------


def test_closed_loop_truth_matches_scripted_cycle_pose():
    """ClosedLoopCycle's absolute-pose truth agrees with the composed
    per-step transforms of the same commanded motion (the deterministic
    composition invariant, end to end through the plant)."""
    cyc = ClosedLoopCycle("straight", 8, *HW, seed=0)
    H, W = HW
    cx, cy = (W - 1) / 2.0, (H - 1) / 2.0
    for _ in range(5):
        fr = cyc.observe()
        yaw, dx, dy = cyc.pose()
        want = np.array([
            transform_rho_theta(float(r), float(t), yaw_rad=yaw, dx=dx,
                                dy=dy, cx=cx, cy=cy)
            for r, t in cyc.base.lines_rho_theta], np.float32)
        assert np.allclose(fr.scene.lines_rho_theta, want)
        cyc.advance(0.05)


def test_closed_loop_blind_drifts_off():
    """No steering = the disturbance wins: cross-track error exceeds any
    controlled run's by a wide margin."""
    cyc = standard_closed_loop("straight", 48, seed=0)
    for _ in range(48):
        cyc.observe()
        cyc.advance(None)
    assert cyc.max_cross_track_m > 0.6


def test_closed_loop_oracle_converges():
    """Steering from the analytic truth (a perfect detector) pulls the
    off-center start toward the lane center and keeps it there — the
    controller gains + world-model signs close the loop stably."""
    cyc = standard_closed_loop("straight", 48, seed=0)
    ctl = LateralController(clock=lambda: float(cyc.t))
    for _ in range(48):
        fr = cyc.observe()
        cmd = ctl.command(fr.scene.lines_rho_theta)
        cyc.advance(cmd.curvature)
    ct = cyc.cross_track
    assert cyc.max_cross_track_m <= 0.30       # never worse than start
    assert float(ct[-12:].max()) < 0.15        # settled by the end
    assert cyc.mean_cross_track_m < 0.12


@pytest.mark.slow
def test_closed_loop_detector_converges_and_is_reproducible():
    """The REAL spine — detector -> tracker -> controller -> plant —
    converges like the oracle, and two identical runs produce
    bit-identical trajectories (seeded rngs + virtual clock only)."""
    def run():
        cyc = standard_closed_loop("straight", 40, seed=0)
        ctl = LateralController(clock=lambda: float(cyc.t))
        tp = TrackingPipeline(_cfg())
        for _ in range(40):
            fr = cyc.observe()
            tf = tp.process(fr.scene.image, controller=ctl)
            cyc.advance(tf.steering.curvature)
        return cyc

    a, b = run(), run()
    assert a.max_cross_track_m <= 0.30
    assert float(a.cross_track[-10:].max()) < 0.15
    assert a.trajectory == b.trajectory


def test_closed_loop_dropout_costs_trajectory_error():
    """A mid-transient camera blackout measurably degrades the oracle
    trajectory vs the same cycle without it — detection failures now
    cost trajectory error, which is the point of this PR."""
    def run(drop):
        cyc = ClosedLoopCycle("straight", 32, *HW, seed=0,
                              dropout_frames=drop)
        ctl = LateralController(clock=lambda: float(cyc.t))
        for _ in range(32):
            fr = cyc.observe()
            if fr.dropout:
                cmd = ctl.hold()
            else:
                cmd = ctl.command(fr.scene.lines_rho_theta)
            cyc.advance(cmd.curvature)
        return cyc.mean_cross_track_m

    assert run(tuple(range(6, 12))) > run(()) * 1.05


def test_closed_loop_advance_none_holds_decayed():
    cyc = ClosedLoopCycle("straight", 8, *HW, seed=0)
    cyc.advance(0.5)
    assert cyc.trajectory[-1][3] == pytest.approx(0.5)
    cyc.advance(None)
    assert cyc.trajectory[-1][3] == pytest.approx(
        0.5 * cyc.cfg.hold_decay)
    cyc.advance(None)
    assert cyc.trajectory[-1][3] == pytest.approx(
        0.5 * cyc.cfg.hold_decay ** 2)


def test_closed_loop_curvature_clamped():
    cyc = ClosedLoopCycle("straight", 4, *HW, seed=0)
    cyc.advance(99.0)
    assert cyc.trajectory[-1][3] == cyc.cfg.max_curvature


# --- service steering -------------------------------------------------------


def _service(clock, **kw):
    kw.setdefault("buckets", (HW,))
    kw.setdefault("batch_size", 1)
    kw.setdefault("prefetch", False)
    kw.setdefault("steering", ControlConfig())
    return DetectionService(_cfg(), clock=clock, **kw)


def _pump(svc, clock, req, cost=0.02):
    svc.step()
    grid = svc.grids[HW]
    if grid.in_flight is not None:
        clock.advance(cost)
        svc.drain()
    for _ in range(4):
        if req.is_terminal:
            break
        svc.step()
        svc.drain()
    assert req.is_terminal
    return req


def test_service_attaches_steering_on_session_requests():
    clock = VirtualClock()
    svc = _service(clock)
    img = make_scenario("straight", *HW, seed=0).image
    try:
        for t in range(3):
            clock.advance(0.1)
            req = DetectionRequest(uid=t, frame=img, deadline_s=0.5,
                                   session_id="ego")
            svc.submit(req)
            _pump(svc, clock, req)
            assert req.status is RequestStatus.DONE
            assert req.steering is not None
            assert req.steering.t == clock()
        # the warm session steers from smoothed tracks: a pair fit
        assert req.steering.source == "pair"
        # non-session requests carry no steering
        solo = DetectionRequest(uid=99, frame=img, deadline_s=0.5)
        svc.submit(solo)
        _pump(svc, clock, solo)
        assert solo.steering is None
    finally:
        svc.close()


def test_service_coast_and_refusal_keep_steering():
    """Overload: ladder-on coasts carry a FRESH command from predicted
    tracks; refusals carry a decayed hold — the vehicle is never left
    without a lateral command mid-session."""
    clock = VirtualClock()
    svc = _service(clock)
    grid = svc.grids[HW]
    img = make_scenario("straight", *HW, seed=0).image
    try:
        for t in range(8):      # warm the tracker past coast_hits
            clock.advance(0.1)
            req = DetectionRequest(uid=t, frame=img, deadline_s=0.5,
                                   session_id="ego")
            svc.submit(req)
            _pump(svc, clock, req)
        k_warm = req.steering.curvature
        # overload: estimator says a dispatch cannot meet any deadline
        grid.est_s, grid.est_measured = 5.0, True
        coasts, holds = [], []
        for t in range(8, 14):
            clock.advance(0.1)
            req = DetectionRequest(uid=t, frame=img, deadline_s=0.1,
                                   session_id="ego")
            svc.submit(req)
            svc.step()
            assert req.is_terminal
            assert req.steering is not None
            if req.status is RequestStatus.DEGRADED_COAST:
                coasts.append(req)
            else:
                assert req.status is RequestStatus.DEADLINE_EXCEEDED
                holds.append(req)
        assert coasts and holds     # budget covers some, not all
        for r in coasts:
            assert r.steering.fresh and r.tracks
        ages = [r.steering.age for r in holds]
        assert ages == sorted(ages)     # hold chain: ages increase
        assert all(r.steering.source == "hold" for r in holds)
        # decay compounds off the last fresh command
        assert abs(holds[0].steering.curvature) <= abs(k_warm) + 1e-9
    finally:
        svc.close()


def test_service_ladder_off_refusals_still_hold():
    clock = VirtualClock()
    svc = _service(clock, ladder=False)
    grid = svc.grids[HW]
    img = make_scenario("straight", *HW, seed=0).image
    try:
        clock.advance(0.1)
        req = DetectionRequest(uid=0, frame=img, deadline_s=0.5,
                               session_id="ego")
        svc.submit(req)
        _pump(svc, clock, req)
        grid.est_s, grid.est_measured = 5.0, True
        clock.advance(0.1)
        shed = DetectionRequest(uid=1, frame=img, deadline_s=0.1,
                                session_id="ego")
        svc.submit(shed)
        svc.step()
        assert shed.status is RequestStatus.DEADLINE_EXCEEDED
        assert shed.steering is not None
        assert shed.steering.source == "hold"
    finally:
        svc.close()


def test_end_session_drops_controller():
    clock = VirtualClock()
    svc = _service(clock)
    img = make_scenario("straight", *HW, seed=0).image
    try:
        req = DetectionRequest(uid=0, frame=img, session_id="ego")
        svc.submit(req)
        _pump(svc, clock, req)
        assert "ego" in svc.controllers
        svc.end_session("ego")
        assert "ego" not in svc.controllers
    finally:
        svc.close()


def test_tracking_pipeline_steering_hook():
    """TrackingPipeline.process(frame, controller=...) attaches the
    command, steering from tracks once confirmed and from raw
    detections during warmup."""
    cyc = standard_closed_loop("straight", 6, seed=0)
    ctl = LateralController(clock=lambda: float(cyc.t))
    tp = TrackingPipeline(_cfg())
    sources = []
    for _ in range(4):
        fr = cyc.observe()
        tf = tp.process(fr.scene.image, controller=ctl)
        assert tf.steering is not None
        sources.append(tf.steering.source)
        cyc.advance(tf.steering.curvature)
    assert sources[0] == "pair"     # raw detections cover warmup
    # once the tracker confirms, control_peaks prefers tracks
    assert tp.tracker.tracks
    peaks, valid = tf.control_peaks
    assert peaks.shape[0] == len(tf.tracks)
