"""Checkpoint store: atomic save, async, retention, restore."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, latest_step, restore, save


def tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.normal(size=(8, 8)), jnp.float32),
                   "b": jnp.asarray(rng.normal(size=(8,)), jnp.bfloat16)},
        "step": jnp.int32(7),
    }


def test_save_restore_roundtrip(tmp_path):
    state = tree()
    save(state, str(tmp_path), 7)
    assert latest_step(str(tmp_path)) == 7
    got = restore(str(tmp_path), state)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_restore_validates_shapes(tmp_path):
    save(tree(), str(tmp_path), 1)
    bad = tree()
    bad["params"]["w"] = jnp.zeros((4, 4))
    with pytest.raises(ValueError, match="shape"):
        restore(str(tmp_path), bad)


def test_async_manager_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for step in (10, 20, 30, 40):
        mgr.save_async(tree(step), step)
    mgr.wait()
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(tmp_path)
        if d.startswith("step_")
    )
    assert steps == [30, 40]
    got = mgr.restore_latest(tree())
    np.testing.assert_array_equal(
        np.asarray(got["params"]["w"]), np.asarray(tree(40)["params"]["w"]))


def test_atomicity_no_tmp_left(tmp_path):
    save(tree(), str(tmp_path), 5)
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_async_overlaps_and_is_consistent(tmp_path):
    """Mutating state after save_async must not corrupt the snapshot."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    state = tree(1)
    mgr.save_async(state, 1)
    # "train" mutates immediately
    state = jax.tree.map(lambda x: x * 0, state)
    mgr.wait()
    got = mgr.restore_latest(tree())
    np.testing.assert_array_equal(
        np.asarray(got["params"]["w"]), np.asarray(tree(1)["params"]["w"]))
