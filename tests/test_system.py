"""End-to-end system behaviour: the paper's pipeline + the LM framework."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core import LineDetector, PipelineConfig
from repro.data import TokenPipelineConfig, TokenStream
from repro.data.images import frame_stream
from repro.models import build
from repro.serve import Engine, Request
from repro.train import AdamWConfig, make_train_step
from repro.train.state import init_train_state


def test_video_stream_line_detection():
    """The paper's deployment loop: a frame stream, lines every frame."""
    det = LineDetector(PipelineConfig())
    hits = 0
    for scene in frame_stream(4, 96, 128, seed=11):
        res = det.detect(jnp.asarray(scene.image, jnp.float32))
        if int(res.valid.sum()) > 0:
            hits += 1
    assert hits >= 3


@pytest.mark.slow
def test_train_then_serve_roundtrip():
    """Train a tiny LM on the synthetic pipeline until it learns the ramp
    structure, then serve it and check generations continue ramps."""
    cfg = get_smoke("yi-9b").replace(vocab=64)
    m = build(cfg)
    stream = TokenStream(TokenPipelineConfig(
        vocab=64, seq_len=32, global_batch=8, seed=1))
    state = init_train_state(m.init(jax.random.PRNGKey(0)))
    # optimization-quality retune (ROADMAP follow-up): grad norms on this
    # tiny noisy mixture sit at 2-4 (always clipped to 1.0), so the raw
    # second moment is stale at b2=0.95's horizon and the effective step
    # oscillates.  A tighter b2 plus longer warmup settles the trajectory
    # (loss ratio 0.73 -> 0.69 in 200 steps), and 100 more steps of the
    # settled schedule buy the margin that also pins the ramp continuation:
    # ratio 0.60, next-token 18.
    step = jax.jit(make_train_step(
        m, AdamWConfig(peak_lr=5e-3, warmup_steps=20, decay_steps=600,
                       b2=0.99)))
    first = last = None
    for s in range(300):
        batch = {k: jnp.asarray(v) for k, v in stream.batch_at(s).items()}
        state, metrics = step(state, batch)
        if first is None:
            first = float(metrics["loss"])
        last = float(metrics["loss"])
    assert last < 0.7 * first, (first, last)

    # serve: after a stride-1 ramp prompt, every pattern family in the
    # training mixture (ramp, motif, noisy copy) predicts 18 next — an
    # untrained model emits an unrelated constant (argmax collapse).
    eng = Engine(m, state.params, n_slots=2, max_len=64,
                 prefill_buckets=(8, 16))
    req = Request(uid=0, prompt=[10, 11, 12, 13, 14, 15, 16, 17],
                  max_new_tokens=4)
    eng.submit(req)
    eng.run()
    assert len(req.output) == 4
    assert req.output[0] == 18, req.output
