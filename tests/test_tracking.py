"""Temporal tracking layer: deterministic drive-cycle harness.

Everything here runs on fixed seeds and analytic trajectories — the drive
cycles are bit-reproducible, the tracker consults no clock and no RNG, and
the detector is deterministic, so every assertion is exact-replayable (the
acceptance bar: 3 identical runs in a row).

Covered:
  * drive-cycle geometry: exact (rho, theta) trajectory transforms,
    determinism, dropout/burst bookkeeping;
  * LaneTracker lifecycle: birth -> confirm -> coast -> kill, coasting
    through dropout frames, zero ID switches on clean cycles;
  * prediction-gated Hough: bit-exactness with the full sweep when the
    gate covers every theta bin, full-sweep fallback on gate overflow;
  * the temporal win: tracked F1 >= per-frame F1 on the noisy families
    (rain / night / glare) of the standard drive cycle;
  * hypothesis properties: association is one-to-one and gate-respecting
    for arbitrary detection sets; filter state is invariant under theta
    wrap ((rho, theta) vs (-rho, theta +- pi)).
"""

import dataclasses
import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    HoughConfig, LaneTracker, LineDetector, PipelineConfig, Track,
    TrackerConfig, TrackingPipeline, aggregate_scores, merge_peaks,
    score_frame, signed_residual, tracks_as_peaks, wrap_canonical,
)
from repro.core.metrics import rho_theta_residual
from repro.core.plan import DetectionPlan
from repro.data import (
    NOISY_FAMILIES, make_drive_cycle, make_scenario, scenario_names,
    standard_drive_cycle, transform_rho_theta,
)

pytestmark = pytest.mark.tracking

#: Harness resolution: small enough to keep the suite quick, large enough
#: that every family's per-frame detection is healthy (glare/night need
#: more pixels than the 120x160 static-recovery tests use).
HW = (168, 224)

#: Families whose drive-cycle detection is clean enough for *strict*
#: settled recovery (every truth line matched on every settled, non-
#: dropout frame).  The rest are held to a small miss budget instead:
#: curved's polyline approximation and multilane's four near-parallel
#: strokes legitimately drop below strict recovery on single frames.
STRICT_FAMILIES = ("straight", "converging", "dashed", "glare",
                   "occlusion", "fog", "lens_distortion", "empty")


def _cfg() -> PipelineConfig:
    return PipelineConfig(hough=HoughConfig(compact=True, max_edges="auto"))


# --- geometry: drive cycles -------------------------------------------------


def test_transform_rho_theta_is_exact():
    """The analytic line transform agrees with transforming two points of
    the line through the same rigid motion."""
    rng = np.random.default_rng(0)
    cx, cy = 111.5, 83.5
    for _ in range(50):
        rho = rng.uniform(-200, 200)
        theta = rng.uniform(0, math.pi)
        yaw = rng.uniform(-0.2, 0.2)
        dx, dy = rng.uniform(-30, 30, 2)
        rp, tp = transform_rho_theta(rho, theta, yaw_rad=yaw, dx=dx, dy=dy,
                                     cx=cx, cy=cy)
        assert 0.0 <= tp < math.pi
        # two points on the original line, pushed through q = R(p-c)+c+t
        n = np.array([math.cos(theta), math.sin(theta)])
        d = np.array([-n[1], n[0]])
        c, s = math.cos(yaw), math.sin(yaw)
        R = np.array([[c, -s], [s, c]])
        for u in (-50.0, 120.0):
            p = rho * n + u * d
            q = R @ (p - (cx, cy)) + (cx, cy) + (dx, dy)
            assert abs(q[0] * math.cos(tp) + q[1] * math.sin(tp) - rp) < 1e-6


def test_drive_cycle_deterministic_and_flagged():
    a = standard_drive_cycle("rain", 12, 96, 128, seed=3)
    b = standard_drive_cycle("rain", 12, 96, 128, seed=3)
    for fa, fb in zip(a, b):
        np.testing.assert_array_equal(fa.scene.image, fb.scene.image)
        np.testing.assert_array_equal(fa.scene.lines_rho_theta,
                                      fb.scene.lines_rho_theta)
        assert (fa.dropout, fa.noise_burst) == (fb.dropout, fb.noise_burst)
    assert [f.t for f in a if f.dropout] == [4, 5, 6]
    assert [f.t for f in a if f.noise_burst] == [8, 9, 10, 11]
    # dropout frames keep their trajectory truth but carry no lane signal
    for f in a:
        assert f.scene.lines_rho_theta.shape == (2, 2)
        if f.dropout:
            assert f.scene.image.max() < 30


def test_drive_cycle_frames_move_and_truth_follows():
    """The warped lane pixels lie on the transformed analytic lines: the
    image motion and the truth trajectory are the same rigid transform."""
    cyc = make_drive_cycle("straight", 8, 120, 160, seed=1,
                           sway_px=8.0, sway_period=10.0, yaw_amp_deg=2.0)
    assert len({f.scene.image.tobytes() for f in cyc}) == len(cyc)
    for f in cyc:
        ys, xs = np.nonzero(f.scene.image >= 230)  # planted stroke pixels
        assert len(xs) > 50
        dists = []
        for rho, theta in f.scene.lines_rho_theta:
            d = np.abs(xs * math.cos(theta) + ys * math.sin(theta) - rho)
            dists.append(d)
        # every bright pixel near one of the lines (stroke half-width 1.6
        # + nearest-neighbour warp rounding)
        assert np.min(dists, axis=0).max() <= 3.0


def test_every_family_makes_drive_cycles():
    for fam in scenario_names():
        cyc = make_drive_cycle(fam, 3, 96, 128, seed=0)
        assert len(cyc) == 3
        for f in cyc:
            assert f.scene.image.shape == (96, 128)
            assert f.scene.image.dtype == np.uint8


# --- tracker unit tests (no detector) ---------------------------------------


def test_wrap_canonical_folds_with_sign():
    assert wrap_canonical(50.0, math.pi + 0.1) == pytest.approx(
        (-50.0, 0.1)
    )
    rho, theta = wrap_canonical(-30.0, -0.2)
    assert (rho, theta) == pytest.approx((30.0, math.pi - 0.2))
    assert wrap_canonical(10.0, 0.5) == (10.0, 0.5)


def test_signed_residual_matches_metrics_magnitudes():
    rng = np.random.default_rng(7)
    for _ in range(100):
        det = (rng.uniform(-200, 200), rng.uniform(-1, math.pi + 1))
        ref = (rng.uniform(-200, 200), rng.uniform(0, math.pi))
        drho, dth = signed_residual(det, ref)
        mrho, mth = rho_theta_residual(det, ref)
        assert abs(drho) == pytest.approx(mrho)
        assert abs(dth) == pytest.approx(mth)


def test_merge_peaks_collapses_doublets():
    """A stroke's two raster side-peaks merge to the centerline; distinct
    lanes stay distinct; a doublet straddling the theta seam merges too."""
    doublet = np.array([[100.0, 0.5], [104.0, 0.5],
                        [-210.0, 1.4]])
    merged = merge_peaks(doublet, tol_rho=6.0, tol_theta_deg=2.5)
    assert merged.shape == (2, 2)
    assert merged[0] == pytest.approx((102.0, 0.5))
    seam = np.array([[60.0, 0.01], [-62.0, math.pi - 0.01]])
    merged = merge_peaks(seam, tol_rho=6.0, tol_theta_deg=2.5)
    assert merged.shape == (1, 2)
    drho, dth = rho_theta_residual(tuple(merged[0]), (61.0, 0.0))
    assert drho < 1.1 and dth < 0.02


def _feed(tracker: LaneTracker, dets) -> list[Track]:
    return tracker.step(np.asarray(dets, np.float64).reshape(-1, 2))


def test_lifecycle_birth_confirm_coast_kill():
    cfg = TrackerConfig(confirm_hits=2, max_misses=3, coast_hits=4)
    trk = LaneTracker(cfg)
    det = [(80.0, 0.6)]
    rep = _feed(trk, det)
    assert len(rep) == 1 and not rep[0].confirmed     # tentative birth
    rep = _feed(trk, det)
    assert rep[0].confirmed and rep[0].hits == 2      # confirmed
    for _ in range(2):
        rep = _feed(trk, det)
    assert rep[0].hits == 4
    # coast: reported (hits >= coast_hits) through max_misses frames
    for k in range(cfg.max_misses):
        rep = _feed(trk, np.empty((0, 2)))
        assert len(rep) == 1 and rep[0].misses == k + 1, (k, rep)
        assert rep[0].peak == pytest.approx((80.0, 0.6), abs=1e-6)
    # one miss past max_misses kills it
    rep = _feed(trk, np.empty((0, 2)))
    assert rep == [] and trk.tracks == []


def test_tentative_track_dies_on_first_miss():
    trk = LaneTracker(TrackerConfig(confirm_hits=3))
    _feed(trk, [(10.0, 1.0)])
    _feed(trk, np.empty((0, 2)))
    assert trk.tracks == []


def test_barely_confirmed_track_is_not_reported_while_coasting():
    cfg = TrackerConfig(confirm_hits=2, coast_hits=6, max_misses=4)
    trk = LaneTracker(cfg)
    for _ in range(3):
        _feed(trk, [(50.0, 1.0)])
    rep = _feed(trk, np.empty((0, 2)))   # hits=3 < coast_hits
    assert rep == []
    assert len(trk.tracks) == 1          # but it coasts internally


def test_zero_id_switches_on_clean_truth_cycle():
    """Drive the tracker on the analytic trajectories themselves (perfect
    detections): each lane keeps one track id for the whole cycle."""
    cyc = make_drive_cycle("straight", 40, 240, 320, seed=0,
                           lane_change_at=20)
    trk = LaneTracker()
    owner: dict[int, set[int]] = {}
    for f in cyc:
        rep = trk.step(f.scene.lines_rho_theta)
        assert len(rep) == 2
        for j, (rho, theta) in enumerate(f.scene.lines_rho_theta):
            best = min(
                rep, key=lambda t: rho_theta_residual(
                    t.peak, (float(rho), float(theta)))[1]
            )
            owner.setdefault(j, set()).add(best.track_id)
    assert all(len(ids) == 1 for ids in owner.values()), owner


def test_coasting_covers_dropouts_and_reacquires_same_id():
    cyc = make_drive_cycle("straight", 20, 240, 320, seed=0,
                           sway_px=3.0, sway_period=48.0,
                           dropout_frames=(10, 11, 12))
    trk = LaneTracker()
    ids_before, ids_after = set(), set()
    for f in cyc:
        dets = (np.empty((0, 2)) if f.dropout
                else f.scene.lines_rho_theta)
        rep = trk.step(dets)
        # settled frames AND dropout frames both report both lanes,
        # within the harness tolerance of the moving truth
        if f.t >= 2:
            s = score_frame(*tracks_as_peaks(rep),
                            f.scene.lines_rho_theta)
            assert s.fn == 0, (f.t, rep)
            if f.dropout:
                assert all(t.coasting for t in rep)
        if f.t == 9:
            ids_before = {t.track_id for t in rep}
        if f.t == 13:
            ids_after = {t.track_id for t in rep}
    assert ids_before == ids_after != set()


# --- association / wrap properties ------------------------------------------

# hypothesis-driven where available (the toolchain image may lack it — the
# same scoped importorskip discipline as tests/test_detection_service.py);
# deterministic rng sweeps keep both properties covered either way.


def _check_one_to_one_gate_respecting(dets0: np.ndarray, dets1: np.ndarray
                                      ) -> None:
    """With alpha=1 a matched track lands exactly on its detection: after
    two arbitrary frames, updated tracks sit on *distinct* detections of
    frame 1 (one-to-one), and each was within the gate of the frame-0
    detection that birthed it (gate-respecting: the filter never
    teleports)."""
    cfg = TrackerConfig(alpha=1.0, beta=0.0, merge_rho=0.0,
                        gate_rho=12.0, gate_theta_deg=6.0)
    trk = LaneTracker(cfg)
    trk.step(dets0)
    born = {t.track_id: t.peak for t in trk.tracks}
    rep = trk.step(dets1)
    matched = [t for t in rep if t.age == 2 and t.misses == 0]
    claimed: list[int] = []
    for t in matched:
        # lands exactly on one frame-1 detection
        res = [rho_theta_residual(t.peak, tuple(d)) for d in dets1]
        hits = [i for i, (dr, dt) in enumerate(res)
                if dr < 1e-6 and dt < 1e-6]
        assert hits, (t, dets1)
        claimed.append(hits[0])
        # and its birth position was inside the gate of that detection
        dr, dt = rho_theta_residual(born[t.track_id],
                                    tuple(dets1[hits[0]]))
        assert dr <= cfg.gate_rho + 1e-6
        assert dt <= math.radians(cfg.gate_theta_deg) + 1e-6
    assert len(claimed) == len(set(claimed))   # one-to-one


def _check_wrap_invariance(frames: list[np.ndarray], seed: int) -> None:
    """Feeding (rho, theta) vs the equivalent (-rho, theta +- pi) — per
    detection, chosen at random — yields identical canonical filter
    states, ids, and lifecycle counters."""
    rng = np.random.default_rng(seed)
    a, b = LaneTracker(), LaneTracker()
    for dets in frames:
        flips = rng.random(dets.shape[0]) < 0.5
        sign = np.where(rng.random(dets.shape[0]) < 0.5, 1.0, -1.0)
        wrapped = dets.copy()
        wrapped[flips, 0] = -wrapped[flips, 0]
        wrapped[flips, 1] = wrapped[flips, 1] + sign[flips] * math.pi
        a.step(dets)
        b.step(wrapped)
    sa, sb = a.tracks, b.tracks
    assert len(sa) == len(sb)
    for ta, tb in zip(sa, sb):
        assert ta.track_id == tb.track_id
        assert (ta.hits, ta.misses, ta.age, ta.confirmed) == (
            tb.hits, tb.misses, tb.age, tb.confirmed)
        assert ta.rho == pytest.approx(tb.rho, abs=1e-9)
        assert ta.theta == pytest.approx(tb.theta, abs=1e-12)
        assert ta.drho == pytest.approx(tb.drho, abs=1e-9)
        assert ta.dtheta == pytest.approx(tb.dtheta, abs=1e-12)


def _rng_peaks(rng: np.random.Generator, n: int) -> np.ndarray:
    return np.column_stack([
        rng.uniform(-250.0, 250.0, n),
        rng.uniform(-0.5, math.pi + 0.5, n),
    ]) if n else np.empty((0, 2))


def test_association_one_to_one_gate_respecting_sweep():
    rng = np.random.default_rng(42)
    for _ in range(60):
        _check_one_to_one_gate_respecting(
            _rng_peaks(rng, int(rng.integers(0, 8))),
            _rng_peaks(rng, int(rng.integers(0, 8))),
        )


def test_wrap_invariance_sweep():
    rng = np.random.default_rng(43)
    for case in range(40):
        frames = [_rng_peaks(rng, int(rng.integers(0, 6)))
                  for _ in range(int(rng.integers(1, 5)))]
        _check_wrap_invariance(frames, seed=case)


def test_association_property_hypothesis():
    """Property form over arbitrary detection sets (skips w/o hypothesis)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    peaks = st.lists(
        st.tuples(st.floats(-250.0, 250.0),
                  st.floats(-0.5, math.pi + 0.5)),
        min_size=0, max_size=8,
    ).map(lambda rows: np.asarray(rows, np.float64).reshape(-1, 2))

    @settings(max_examples=30, deadline=None)
    @given(peaks, peaks)
    def prop(dets0, dets1):
        _check_one_to_one_gate_respecting(dets0, dets1)

    prop()


def test_wrap_invariance_property_hypothesis():
    """Property form of the theta-wrap invariance (skips w/o hypothesis)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    peaks = st.lists(
        st.tuples(st.floats(-250.0, 250.0),
                  st.floats(-0.5, math.pi + 0.5)),
        min_size=0, max_size=6,
    ).map(lambda rows: np.asarray(rows, np.float64).reshape(-1, 2))

    @settings(max_examples=30, deadline=None)
    @given(st.lists(peaks, min_size=1, max_size=5),
           st.integers(0, 2 ** 31 - 1))
    def prop(frames, seed):
        _check_wrap_invariance(frames, seed)

    prop()


# --- prediction-gated Hough -------------------------------------------------


def test_gated_full_cover_is_bit_exact():
    """A gate covering every theta bin is the full sweep, bit for bit —
    gather and scatter are both identities."""
    cfg = _cfg()
    img = jnp.asarray(make_scenario("converging", 96, 128).image,
                      jnp.float32)
    full = DetectionPlan.build(cfg, 96, 128)
    n_theta = cfg.hough.n_theta
    gated = full.with_theta_band(n_theta)
    res_f = full.run(img)
    res_g = gated.run(img, np.arange(n_theta, dtype=np.int32))
    for a, b in ((res_f.peaks, res_g.peaks), (res_f.valid, res_g.valid),
                 (res_f.lines, res_g.lines), (res_f.edges, res_g.edges)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_gated_narrow_band_matches_full_when_peaks_inside():
    """When every true peak lies inside the gate, the gated detections
    equal the full sweep's (same max -> same relative threshold)."""
    cfg = _cfg()
    sc = make_scenario("straight", 96, 128)
    img = jnp.asarray(sc.image, jnp.float32)
    full = DetectionPlan.build(cfg, 96, 128)
    res_f = full.run(img)
    band = 48
    bins = sorted({
        (int(round(math.degrees(t))) + d) % 180
        for _, t in sc.lines_rho_theta for d in range(-10, 11)
    })
    bins = (bins + [bins[0]] * band)[:band]
    res_g = full.with_theta_band(band).run(
        img, np.asarray(bins, np.int32))
    np.testing.assert_array_equal(np.asarray(res_f.peaks),
                                  np.asarray(res_g.peaks))
    np.testing.assert_array_equal(np.asarray(res_f.valid),
                                  np.asarray(res_g.valid))


def test_gate_overflow_falls_back_to_full_sweep():
    """A theta band too small for the confirmed tracks' union must fall
    back to the full sweep (gating is a perf hook, never a correctness
    dependence)."""
    cfg = _cfg()
    tp = TrackingPipeline(cfg, height=96, width=128, theta_band=4)
    sc = make_scenario("straight", 96, 128)
    for _ in range(5):
        tp.process(sc.image)
    assert tp.full_frames == 5 and tp.gated_frames == 0
    assert len(tp.tracker.confirmed_tracks) >= 1   # tracking still works


def test_tracking_pipeline_engages_gate_and_recovers_after_loss():
    cfg = _cfg()
    tp = TrackingPipeline(cfg, height=96, width=128, theta_band=48)
    sc = make_scenario("straight", 96, 128)
    for _ in range(4):
        fr = tp.process(sc.image)
    assert fr.gated and tp.gated_frames == 2 and tp.full_frames == 2
    # dropout long enough to kill every track -> full sweep again
    dark = np.full((96, 128), 12, np.uint8)
    for _ in range(TrackerConfig().max_misses + 2):
        fr = tp.process(dark)
    assert not fr.gated and tp.tracker.tracks == []
    # reacquire: the rescan window keeps the sweep ungated while the
    # replacement tracks rebirth + confirm, then the gate re-engages
    for _ in range(TrackerConfig().rescan_frames + 3):
        fr = tp.process(sc.image)
    assert fr.gated


# --- the drive-cycle harness (detector in the loop) -------------------------


@pytest.fixture(scope="module")
def harness_cfg():
    return _cfg()


@pytest.mark.parametrize("family", scenario_names())
def test_trajectory_recovery_on_drive_cycle(family, harness_cfg):
    """Tracked recovery within the (4 px, 3 deg) harness tolerance on the
    standard drive cycle: strict families miss zero truth lines on every
    settled non-dropout frame; the rest stay within a small miss budget.
    Dropout frames are covered by coasting (scored too, except for the
    families whose coasts are not yet mature at the dropout window)."""
    cyc = standard_drive_cycle(family, 18, *HW, seed=0)
    tp = TrackingPipeline(harness_cfg, height=HW[0], width=HW[1])
    missed = 0
    scored = 0
    for f in cyc:
        rep = tp.process(f.scene.image).tracks
        if f.t < 4:
            continue
        # dropout frames judge the coasting *extrapolation*: double the
        # harness tolerance (a lane change continues under the blackout;
        # per-frame detection recovers nothing at any tolerance there)
        tol = dict(tol_rho=8.0, tol_theta_deg=6.0) if f.dropout else {}
        s = score_frame(*tracks_as_peaks(rep), f.scene.lines_rho_theta,
                        **tol)
        scored += 1
        missed += s.fn
        if family in STRICT_FAMILIES:
            assert s.fn == 0, (family, f.t, s)
    if family not in STRICT_FAMILIES:
        assert scored == 14
        assert missed <= 8, (family, missed)


@pytest.mark.parametrize("family", NOISY_FAMILIES)
def test_tracked_f1_beats_per_frame_on_noisy_cycles(family, harness_cfg):
    """The temporal claim, quantified: on the noisy drive cycles (dropout
    + noise bursts), tracked F1 >= per-frame F1 — coasting covers the
    blackout and the maturity bar suppresses burst flicker."""
    cyc = standard_drive_cycle(family, 24, *HW, seed=0)
    det = LineDetector(harness_cfg)
    tp = TrackingPipeline(harness_cfg, height=HW[0], width=HW[1])
    per, trk, trk_reports = [], [], []
    for f in cyc:
        res = det.detect(jnp.asarray(f.scene.image, jnp.float32))
        per.append(score_frame(np.asarray(res.peaks),
                               np.asarray(res.valid),
                               f.scene.lines_rho_theta))
        rep = tp.process(f.scene.image).tracks
        trk_reports.append(rep)
        trk.append(score_frame(*tracks_as_peaks(rep),
                               f.scene.lines_rho_theta))
    per_f1 = aggregate_scores(per)["f1"]
    trk_f1 = aggregate_scores(trk)["f1"]
    assert trk_f1 >= per_f1, (family, trk_f1, per_f1)
    # and the dropout window specifically is covered by coasting, judged
    # at the extrapolation tolerance (2x harness: the lane change keeps
    # moving under the blackout) — per-frame detection has NOTHING there
    for f in cyc:
        if not f.dropout:
            continue
        rep = trk_reports[f.t]
        s = score_frame(*tracks_as_peaks(rep), f.scene.lines_rho_theta,
                        tol_rho=8.0, tol_theta_deg=6.0)
        assert s.fn == 0, (family, f.t, s)
        assert per[f.t].tp == 0
    # steady state runs gated (2 cold-start full sweeps + the re-
    # acquisition sweeps after the blackout window)
    assert tp.gated_frames >= len(cyc) - 8, (tp.gated_frames,
                                             tp.full_frames)


def test_tracking_is_deterministic_across_reruns():
    """Same cycle, twice: identical reported states, ids, and gate path."""
    def run():
        cyc = standard_drive_cycle("rain", 12, 96, 128, seed=5)
        tp = TrackingPipeline(_cfg(), height=96, width=128)
        out = []
        for f in cyc:
            fr = tp.process(f.scene.image)
            out.append((fr.gated, [dataclasses.astuple(t)
                                   for t in fr.tracks]))
        return out
    assert run() == run()
