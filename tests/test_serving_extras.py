"""Extra serving coverage: long-context ring engine, int8 weight serving,
paper-platform configs."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.core.quantize import quantize_weights_int8
from repro.models import build
from repro.serve import Engine, Request


def test_engine_ring_cache_long_context():
    """SWA arch served with a ring cache: generation runs past the window
    with O(window) cache memory and matches the linear-cache engine inside
    the window-constrained regime."""
    cfg = get_smoke("h2o-danube-1.8b")   # window=16
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))

    prompt = [2, 3, 5, 7]
    r_lin = Request(uid=0, prompt=list(prompt), max_new_tokens=30)
    e_lin = Engine(m, params, n_slots=1, max_len=64)
    e_lin.submit(r_lin)
    e_lin.run()

    r_ring = Request(uid=0, prompt=list(prompt), max_new_tokens=30)
    e_ring = Engine(m, params, n_slots=1, max_len=64, ring=True)
    e_ring.submit(r_ring)
    e_ring.run()

    # ring cache really is window-sized
    k = e_ring.cache["blocks"]["0_attn"]["k"]
    assert k.shape[-2] == cfg.window
    # greedy trajectories agree (attention only ever sees the window)
    assert r_ring.output == r_lin.output


def test_int8_weight_serving_accuracy():
    """Weight-only int8 (paper §4.4 on the serving path): greedy decode
    logits stay close to bf16 serving; top-1 tokens match."""
    cfg = get_smoke("yi-9b")
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    q, dequant = quantize_weights_int8(params, compute_dtype=cfg.cdtype)
    params_q = dequant(q["q"], q["s"])

    # teacher-forced: both paths see the same tokens, so errors measure
    # quantization alone (no trajectory-divergence amplification)
    B, L = 2, 24
    rng = jax.random.PRNGKey(3)
    toks = jax.random.randint(rng, (B, 12), 0, cfg.vocab, dtype=jnp.int32)
    cache_a = m.init_cache(B, L)
    cache_b = m.init_cache(B, L)
    errs, la_all = [], []
    matches = total = 0
    for t in range(12):
        pos = jnp.full((B,), t, jnp.int32)
        la, cache_a = m.decode_step(params, toks[:, t], cache_a, pos)
        lb, cache_b = m.decode_step(params_q, toks[:, t], cache_b, pos)
        errs.append(float(jnp.max(jnp.abs(la - lb))))
        la_all.append(la)
        matches += int((jnp.argmax(la, -1) == jnp.argmax(lb, -1)).sum())
        total += B
    std = float(jnp.std(jnp.stack(la_all)))
    assert max(errs) < 0.5 * std, (max(errs), std)
    assert matches >= int(0.7 * total), (matches, total)


def test_paper_platform_configs_detect():
    """Every paper-platform execution variant detects the planted lines."""
    import math

    from repro.configs.paper_lines import PLATFORMS
    from repro.core import LineDetector
    from repro.data.images import synthetic_road

    scene = synthetic_road(96, 128, seed=3)
    for name, pcfg in PLATFORMS.items():
        det = LineDetector(pcfg)
        img = jnp.asarray(
            scene.image,
            jnp.int32 if pcfg.canny.integer else jnp.float32,
        )
        res = det.detect(img)
        got = [
            (float(r), math.degrees(float(t)))
            for (r, t), ok in zip(np.asarray(res.peaks),
                                  np.asarray(res.valid)) if ok
        ]
        for rho, theta in scene.lines_rho_theta:
            deg = math.degrees(theta)
            assert any(
                abs(r - rho) <= 5 and abs(t - deg) <= 3 for r, t in got
            ), (name, rho, deg, got)
