"""Fused canny -> compact -> vote hot path (PR 8).

Layers under test, bottom-up:

  * kernel parity — ``ops.fused_detect`` (xla oracle, interpret Pallas
    body) against the staged ``compact_edges`` construction, bit-for-bit;
  * ``compact_raster`` — the index-scatter compaction against the generic
    row-scatter ``compact_edges`` on the same weights;
  * corridor filtering — ``corridor_keep`` geometry, the filtered vote,
    and the all-pass ``full_corridors`` identity;
  * plan math — ``fused_hough`` / ``fused_hough_tiered`` bit-exact with
    the staged transforms at full coverage (single frame, batch, gated
    band, overflow of the cap tier);
  * tracker corridors — health rules (cold start, rescan, coasting,
    overflow) and window geometry;
  * pipeline/service — the fused plan engages in steady state and the
    answers match the staged configuration exactly on a clean cycle;
  * quantized tiers — ``CannyConfig.grad_dtype`` wiring sanity.

Deterministic seeded loops throughout (no hypothesis on this host).
"""

import dataclasses
import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CannyConfig, HoughConfig, PipelineConfig, canny, hough_transform,
    hough_transform_tiered,
)
from repro.core.hough import (
    CORRIDOR_INF, full_corridors, fused_hough, fused_hough_tiered,
)
from repro.core.tracking import LaneTracker, TrackerConfig, TrackingPipeline
from repro.data import make_drive_cycle, synthetic_road
from repro.kernels import ops, ref
from repro.kernels.hough_vote import compact_edges

pytestmark = pytest.mark.fused

CANNY = CannyConfig()


def _img(h=120, w=160, seed=0, noise=4.0):
    return jnp.asarray(
        np.asarray(synthetic_road(h, w, seed=seed, noise=noise).image,
                   np.float32)
    )


def _staged_compact(img, max_edges, corridors=None):
    """The staged construction of the fused output: canny -> weights ->
    (optional corridor mask) -> generic row-scatter compaction."""
    edges = canny(img, dataclasses.replace(CANNY, impl="xla"))
    H, W = edges.shape[-2:]
    jj, ii = jnp.meshgrid(jnp.arange(W), jnp.arange(H))
    xy = jnp.stack(
        [jj.ravel(), ii.ravel(), jnp.ones(H * W, jnp.int32)], axis=1
    ).astype(jnp.float32)
    flat = edges.reshape(edges.shape[:-2] + (H * W,))
    w = (flat >= 250.0).astype(jnp.float32)
    if corridors is not None:
        w = w * ref.corridor_keep(xy, corridors).astype(jnp.float32)
    return compact_edges(xy, w, max_edges=max_edges)


# --- kernel parity ----------------------------------------------------------


def test_fused_detect_matches_staged_compaction():
    for seed in range(4):
        img = _img(seed=seed)
        got = ops.fused_detect(img, None, cfg=CANNY, edge_threshold=250.0,
                               max_edges=256, impl="xla")
        want = _staged_compact(img, 256)
        np.testing.assert_array_equal(np.asarray(got[0]),
                                      np.asarray(want[0]))
        np.testing.assert_array_equal(np.asarray(got[1]),
                                      np.asarray(want[1]))


def test_fused_detect_batched_and_overflow():
    imgs = jnp.stack([_img(seed=s) for s in range(3)])
    for max_edges in (16, 256):  # 16 overflows: same trailing-edge drop
        got = ops.fused_detect(imgs, None, cfg=CANNY, edge_threshold=250.0,
                               max_edges=max_edges, impl="xla")
        want = _staged_compact(imgs, max_edges)
        np.testing.assert_array_equal(np.asarray(got[0]),
                                      np.asarray(want[0]))
        np.testing.assert_array_equal(np.asarray(got[1]),
                                      np.asarray(want[1]))


def test_fused_detect_interpret_matches_oracle():
    img = _img(96, 128, seed=2)
    cors = jnp.asarray(np.array([[1.0, 0.0, 30.0, 100.0]], np.float32))
    for corridors in (None, cors):
        a = ops.fused_detect(img, corridors, cfg=CANNY,
                             edge_threshold=250.0, max_edges=128,
                             impl="interpret")
        b = ops.fused_detect(img, corridors, cfg=CANNY,
                             edge_threshold=250.0, max_edges=128,
                             impl="xla")
        np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
        np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


def test_compact_raster_matches_compact_edges(rng):
    """The index-scatter compaction is bit-identical to the generic
    row-scatter on raster-layout weights — sparse, dense, empty, batched,
    and overflowing."""
    H, W = 24, 32
    jj, ii = jnp.meshgrid(jnp.arange(W), jnp.arange(H))
    xy = jnp.stack(
        [jj.ravel(), ii.ravel(), jnp.ones(H * W, jnp.int32)], axis=1
    ).astype(jnp.float32)
    for density in (0.0, 0.02, 0.3, 1.0):
        w = (rng.random((H * W,)) < density).astype(np.float32)
        for max_edges in (8, 64, 1024):
            a = ops.compact_raster(jnp.asarray(w), width=W,
                                   max_edges=max_edges)
            b = compact_edges(xy, jnp.asarray(w), max_edges=max_edges)
            np.testing.assert_array_equal(np.asarray(a[0]),
                                          np.asarray(b[0]))
            np.testing.assert_array_equal(np.asarray(a[1]),
                                          np.asarray(b[1]))
    wb = (rng.random((3, H * W)) < 0.1).astype(np.float32)
    a = ops.compact_raster(jnp.asarray(wb), width=W, max_edges=32)
    b = compact_edges(xy, jnp.asarray(wb), max_edges=32)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


# --- corridor geometry ------------------------------------------------------


def test_corridor_keep_geometry():
    """A horizontal corridor (theta=0 normal) keeps exactly the x-window;
    any-corridor OR and padding duplication are idempotent."""
    xy = jnp.asarray(
        np.array([[0.0, 5.0], [10.0, 5.0], [20.0, 5.0], [30.0, 5.0]],
                 np.float32)
    )
    cor = jnp.asarray(np.array([[1.0, 0.0, 5.0, 15.0]], np.float32))
    keep = np.asarray(ref.corridor_keep(xy, cor))
    assert keep.tolist() == [False, True, False, False]
    padded = jnp.concatenate([cor, cor, cor], axis=0)
    np.testing.assert_array_equal(
        np.asarray(ref.corridor_keep(xy, padded)), keep
    )
    both = jnp.asarray(np.array(
        [[1.0, 0.0, 5.0, 15.0], [1.0, 0.0, 25.0, 35.0]], np.float32
    ))
    assert np.asarray(ref.corridor_keep(xy, both)).tolist() == [
        False, True, False, True
    ]


def test_full_corridors_pass_everything():
    cors = full_corridors(3)
    assert cors.shape == (3, 4)
    assert (cors[:, 2] == -CORRIDOR_INF).all()
    assert (cors[:, 3] == CORRIDOR_INF).all()
    xy = jnp.asarray(np.array([[0.0, 0.0], [1000.0, 1000.0]], np.float32))
    assert np.asarray(ref.corridor_keep(xy, jnp.asarray(cors))).all()


def test_corridor_filter_drops_off_corridor_votes():
    """With a corridor around only one of two planted lanes, the fused
    votes along the excluded lane collapse while the included lane's
    column is untouched."""
    h, w = 120, 160
    scene = synthetic_road(h, w, seed=0)
    img = jnp.asarray(np.asarray(scene.image, np.float32))
    (rho0, th0), (rho1, th1) = [
        tuple(map(float, p)) for p in scene.lines_rho_theta
    ]
    cfg = HoughConfig(compact=True, max_edges=512, corridors=2, impl="xla")
    only0 = jnp.asarray(np.array([
        [math.cos(th0), math.sin(th0), rho0 - 12.0, rho0 + 12.0],
    ] * 2, np.float32))
    votes = np.asarray(fused_hough(img, CANNY, cfg, corridors=only0))
    staged = np.asarray(hough_transform(
        canny(img, CANNY),
        HoughConfig(compact=True, max_edges=512, impl="xla"),
    ))

    def peak_height(v, rho, th):
        n_rho, n_theta = v.shape
        tb = int(round(th / math.pi * n_theta)) % n_theta
        rb = int(rho + n_rho // 2)  # rho_res=1: bin = rho + rho_max
        lo_r, hi_r = max(rb - 4, 0), min(rb + 5, n_rho)
        lo_t, hi_t = max(tb - 4, 0), min(tb + 5, n_theta)
        return v[lo_r:hi_r, lo_t:hi_t].max()

    assert peak_height(votes, rho0, th0) == peak_height(staged, rho0, th0)
    assert peak_height(votes, rho1, th1) < 0.5 * peak_height(
        staged, rho1, th1
    )


# --- plan math: bit-exactness at full coverage ------------------------------


def test_fused_hough_bit_exact_with_staged():
    cfg = HoughConfig(compact=True, max_edges=512, impl="xla")
    for seed in range(3):
        img = _img(seed=seed)
        fused = fused_hough(img, CANNY, cfg)
        staged = hough_transform(canny(img, CANNY), cfg)
        np.testing.assert_array_equal(np.asarray(fused),
                                      np.asarray(staged))


def test_fused_tiered_bit_exact_full_corridors():
    """Exact-count tiering (host path) against the staged tiered dispatch
    — single frame, batch, and gated band, under all-pass corridors."""
    acfg = HoughConfig(compact=True, max_edges="auto", impl="xla",
                       corridors=4)
    scfg = HoughConfig(compact=True, max_edges="auto", impl="xla")
    cors = jnp.asarray(full_corridors(4))
    img = _img(seed=1)
    imgs = jnp.stack([_img(seed=s) for s in range(3)])
    for x in (img, imgs):
        fused = fused_hough_tiered(x, CANNY, acfg, corridors=cors)
        staged = hough_transform_tiered(canny(x, CANNY), scfg)
        np.testing.assert_array_equal(np.asarray(fused),
                                      np.asarray(staged))
    tb = jnp.asarray((np.arange(40) + 50).astype(np.int32))
    bf = dataclasses.replace(acfg, theta_band=40)
    bs = dataclasses.replace(scfg, theta_band=40)
    fused = fused_hough_tiered(img, CANNY, bf, theta_bins=tb,
                               corridors=cors)
    staged = hough_transform_tiered(canny(img, CANNY), bs, theta_bins=tb)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(staged))


def test_fused_tiered_cap_overflow_matches_staged():
    """When the edge count exceeds the cap tier both dispatches drop the
    same trailing edges — overflow stays bit-exact, not merely close."""
    img = _img(seed=3)
    tiers = (16, 32)  # tiny cap: guaranteed overflow on a real frame
    acfg = HoughConfig(compact=True, max_edges="auto", impl="xla",
                       corridors=2)
    scfg = HoughConfig(compact=True, max_edges="auto", impl="xla")
    fused = fused_hough_tiered(img, CANNY, acfg, tiers,
                               corridors=jnp.asarray(full_corridors(2)))
    staged = hough_transform_tiered(canny(img, CANNY), scfg, tiers)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(staged))


def test_fused_hough_rejects_auto_and_mismatched_corridors():
    img = _img()
    with pytest.raises(ValueError, match="auto"):
        fused_hough(img, CANNY,
                    HoughConfig(compact=True, max_edges="auto"))
    cfg = HoughConfig(compact=True, max_edges=256, corridors=2,
                      impl="xla")
    with pytest.raises(ValueError, match="corridors"):
        fused_hough(img, CANNY, cfg)  # config says 2, argument missing
    with pytest.raises(ValueError, match="corridors"):
        fused_hough(img, CANNY, cfg,
                    corridors=jnp.asarray(full_corridors(3)))  # wrong C


# --- tracker corridors ------------------------------------------------------


def _warm_tracker(n=6, h=120, w=160):
    pipe = TrackingPipeline(
        PipelineConfig(hough=HoughConfig(compact=True, max_edges="auto")),
        height=h, width=w, theta_band=40,
    )
    frame = synthetic_road(h, w, seed=0).image
    for _ in range(n):
        pipe.process(frame)
    return pipe.tracker


def test_tracker_corridor_health_rules():
    cfg = TrackerConfig()
    cold = LaneTracker(cfg)
    assert cold.corridors() is None  # cold start: no confirmed tracks

    tr = _warm_tracker()
    cors = tr.corridors()
    assert cors is not None and cors.shape[1] == 4
    n_live = cors.shape[0]

    # padding repeats the first row up to the requested budget
    padded = tr.corridors(8)
    assert padded.shape == (8, 4)
    np.testing.assert_array_equal(padded[:n_live], cors)
    for k in range(n_live, 8):
        np.testing.assert_array_equal(padded[k], cors[0])

    # overflow of the budget refuses (fall back to the staged sweep)
    assert tr.corridors(max(n_live - 1, 0)) is None

    # a coasting confirmed track poisons the set: miss a frame
    tr.step(np.zeros((0, 2), np.float32), np.zeros((0,), bool))
    assert tr.corridors() is None


def test_tracker_corridor_windows_cover_prediction():
    tr = _warm_tracker()
    cors = tr.corridors()
    half = TrackerConfig().corridor_half_px
    for t, row in zip(tr.tracks, cors):  # corridors cover every live track
        rho_p = t.rho + t.drho
        th_p = t.theta + t.dtheta
        assert row[0] == pytest.approx(math.cos(th_p), abs=1e-6)
        assert row[1] == pytest.approx(math.sin(th_p), abs=1e-6)
        assert row[2] == pytest.approx(rho_p - half, abs=1e-4)
        assert row[3] == pytest.approx(rho_p + half, abs=1e-4)


# --- pipeline + service engagement ------------------------------------------


def test_pipeline_fused_engages_and_matches_gated():
    cfg = PipelineConfig(hough=HoughConfig(compact=True, max_edges="auto"))
    cyc = make_drive_cycle("straight", 12, 120, 160, seed=0)
    fused_pipe = TrackingPipeline(cfg, height=120, width=160,
                                  theta_band=40, fused_corridors=8)
    plain_pipe = TrackingPipeline(cfg, height=120, width=160,
                                  theta_band=40)
    for fr in cyc.frames:
        a = fused_pipe.process(fr.scene.image)
        b = plain_pipe.process(fr.scene.image)
        np.testing.assert_array_equal(np.asarray(a.result.peaks),
                                      np.asarray(b.result.peaks))
        np.testing.assert_array_equal(np.asarray(a.result.valid),
                                      np.asarray(b.result.valid))
    assert fused_pipe.fused_frames > 0
    assert fused_pipe.gated_frames == plain_pipe.gated_frames


def test_pipeline_rejects_fused_config_knobs():
    cfg = PipelineConfig(hough=HoughConfig(compact=True, corridors=4))
    with pytest.raises(ValueError, match="fused_corridors"):
        TrackingPipeline(cfg, theta_band=40)
    with pytest.raises(ValueError, match="theta_band"):
        TrackingPipeline(
            PipelineConfig(hough=HoughConfig(compact=True)),
            theta_band=None, fused_corridors=4,
        )


def test_service_fused_engages_and_matches():
    from repro.serve.detection import (
        DetectionRequest, DetectionService, VirtualClock,
    )

    def run(fused_corridors):
        svc = DetectionService(
            PipelineConfig(
                hough=HoughConfig(compact=True, max_edges="auto")
            ),
            buckets=((120, 160),), batch_size=1, prefetch=False,
            clock=VirtualClock(), gate_band=40,
            fused_corridors=fused_corridors,
        )
        cyc = make_drive_cycle("straight", 10, 120, 160, seed=0)
        out = []
        for fr in cyc.frames:
            req = DetectionRequest(uid=fr.t, frame=fr.scene.image,
                                   session_id="ego")
            svc.submit(req)
            svc.run()
            svc.clock.advance(0.01)
            out.append(req)
        counts = (svc.gated_dispatches, svc.fused_dispatches)
        svc.close()
        return out, counts

    got, (gated_f, fused_f) = run(8)
    ref_, (gated_p, fused_p) = run(None)
    assert fused_f > 0 and fused_p == 0
    for g, r in zip(got, ref_):
        assert g.ok and r.ok
        np.testing.assert_array_equal(np.asarray(g.result.peaks),
                                      np.asarray(r.result.peaks))
        np.testing.assert_array_equal(np.asarray(g.result.valid),
                                      np.asarray(r.result.valid))


# --- quantized gradient tiers ----------------------------------------------


def test_grad_dtype_tiers_run_and_validate():
    img = _img(seed=0)
    base = np.asarray(canny(img, CANNY))
    for grad in ("f16", "int8"):
        out = np.asarray(
            canny(img, dataclasses.replace(CANNY, grad_dtype=grad))
        )
        assert out.shape == base.shape and out.dtype == base.dtype
        # low-precision gradients move few edge pixels on a clean scene
        assert (out != base).mean() < 0.03
    with pytest.raises(ValueError, match="integer"):
        canny(img, dataclasses.replace(
            CANNY, integer=True, grad_dtype="f16"
        ))
    with pytest.raises(ValueError, match="grad_dtype"):
        canny(img, dataclasses.replace(CANNY, grad_dtype="bf8"))
