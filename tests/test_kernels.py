"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.conv2d_gemm import conv2d_gemm
from repro.kernels.flash_attention import flash_attention
from repro.kernels.hough_vote import hough_vote
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.tiled_matmul import tiled_matmul


@pytest.mark.parametrize("m,k,n", [(8, 16, 8), (100, 70, 50), (128, 128, 128),
                                   (33, 129, 65)])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_tiled_matmul_float(rng, m, k, n, dtype):
    x = rng.normal(size=(m, k)).astype(np.float32)
    y = rng.normal(size=(k, n)).astype(np.float32)
    x = jnp.asarray(x, dtype)
    y = jnp.asarray(y, dtype)
    got = tiled_matmul(x, y, interpret=True, bm=32, bn=32, bk=32)
    want = ref.tiled_matmul(x, y)
    tol = 1e-5 if dtype == "float32" else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


@pytest.mark.parametrize("m,k,n", [(16, 32, 16), (64, 48, 32)])
def test_tiled_matmul_int8(rng, m, k, n):
    x = rng.integers(-127, 127, (m, k), dtype=np.int8)
    y = rng.integers(-127, 127, (k, n), dtype=np.int8)
    got = tiled_matmul(jnp.asarray(x), jnp.asarray(y), interpret=True,
                       bm=16, bn=16, bk=16)
    want = ref.tiled_matmul(jnp.asarray(x), jnp.asarray(y))
    assert got.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("hw", [(16, 24), (37, 52), (64, 64)])
@pytest.mark.parametrize("masks", [(1, 3, 3), (3, 5, 5), (3, 7, 7)])
def test_conv2d_gemm_float(rng, hw, masks):
    H, W = hw
    img = rng.normal(size=(H, W)).astype(np.float32)
    m = rng.normal(size=masks).astype(np.float32)
    got = conv2d_gemm(jnp.asarray(img), jnp.asarray(m), interpret=True, bh=8)
    want = ref.conv2d_gemm(jnp.asarray(img), jnp.asarray(m))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_conv2d_gemm_int(rng):
    img = rng.integers(0, 255, (40, 56)).astype(np.int32)
    m = rng.integers(-16, 16, (3, 5, 5)).astype(np.int32)
    got = conv2d_gemm(jnp.asarray(img), jnp.asarray(m), interpret=True, bh=8)
    want = ref.conv2d_gemm(jnp.asarray(img), jnp.asarray(m))
    assert got.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n_pix,n_theta,n_rho", [(64, 45, 60), (200, 180, 150)])
def test_hough_vote(rng, n_pix, n_theta, n_rho):
    xy = rng.uniform(0, 40, (n_pix, 3)).astype(np.float32)
    xy[:, 2] = 1.0
    w = (rng.uniform(size=n_pix) > 0.4).astype(np.float32)
    trig = rng.uniform(-1, 1, (3, n_theta)).astype(np.float32)
    trig[2] = n_rho / 2.5
    got = hough_vote(jnp.asarray(xy), jnp.asarray(w), jnp.asarray(trig),
                     n_rho=n_rho, interpret=True, br=32, bp=64)
    want = ref.hough_vote(jnp.asarray(xy), jnp.asarray(w), jnp.asarray(trig),
                          n_rho=n_rho)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3)


@pytest.mark.parametrize("gqa", [1, 2, 4])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 24),
                                           (False, None)])
def test_flash_attention(rng, gqa, causal, window):
    B, Hq, L, D = 2, 4, 72, 16
    q = rng.normal(size=(B, Hq, L, D)).astype(np.float32)
    k = rng.normal(size=(B, Hq // gqa, L, D)).astype(np.float32)
    v = rng.normal(size=(B, Hq // gqa, L, D)).astype(np.float32)
    got = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                          causal=causal, window=window, interpret=True,
                          bq=16, bk=16)
    want = ref.attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                         causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_decode_offset(rng):
    """Decode: 1 query at the end of a long kv timeline."""
    B, H, Lkv, D = 2, 4, 96, 16
    q = rng.normal(size=(B, H, 1, D)).astype(np.float32)
    k = rng.normal(size=(B, H, Lkv, D)).astype(np.float32)
    v = rng.normal(size=(B, H, Lkv, D)).astype(np.float32)
    got = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                          causal=True, q_offset=Lkv - 1, interpret=True,
                          bq=8, bk=32)
    want = ref.attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                         causal=True, q_offset=Lkv - 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_attention_blockwise_matches_dense_and_grads(rng):
    B, Hq, L, D = 2, 4, 50, 16
    q = jnp.asarray(rng.normal(size=(B, Hq, L, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, 2, L, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, 2, L, D)), jnp.float32)

    out_b = ref.attention_blockwise(q, k, v, causal=True, window=17, block=16)
    out_d = ref.attention(q, k, v, causal=True, window=17)
    np.testing.assert_allclose(np.asarray(out_b), np.asarray(out_d),
                               rtol=1e-4, atol=1e-4)

    def lb(q, k, v):
        return jnp.sum(jnp.sin(ref.attention_blockwise(
            q, k, v, causal=True, window=17, block=16) * 3))

    def ld(q, k, v):
        return jnp.sum(jnp.sin(ref.attention(
            q, k, v, causal=True, window=17) * 3))

    gb = jax.grad(lb, argnums=(0, 1, 2))(q, k, v)
    gd = jax.grad(ld, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gb, gd):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("G", [1, 2])
@pytest.mark.parametrize("chunk", [16, 32])
def test_ssd_scan(rng, G, chunk):
    B, L, H, P, N = 2, 80, 4, 16, 8
    x = (rng.normal(size=(B, L, H, P)) * 0.1).astype(np.float32)
    dt = rng.uniform(0.01, 0.1, (B, L, H)).astype(np.float32)
    A = -rng.uniform(0.5, 1.5, (H,)).astype(np.float32)
    Bm = rng.normal(size=(B, L, G, N)).astype(np.float32)
    C = rng.normal(size=(B, L, G, N)).astype(np.float32)
    ya, sa = ssd_scan(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(A),
                      jnp.asarray(Bm), jnp.asarray(C), chunk=chunk,
                      interpret=True)
    yb, sb = ref.ssd_scan(x, dt, A, Bm, C)
    np.testing.assert_allclose(np.asarray(ya), np.asarray(yb),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(sa), np.asarray(sb),
                               rtol=2e-3, atol=2e-3)


def test_ssd_chunked_ref_matches_sequential(rng):
    B, L, H, P, N, G = 2, 100, 4, 16, 8, 2
    x = (rng.normal(size=(B, L, H, P)) * 0.1).astype(np.float32)
    dt = rng.uniform(0.01, 0.1, (B, L, H)).astype(np.float32)
    A = -rng.uniform(0.5, 1.5, (H,)).astype(np.float32)
    Bm = rng.normal(size=(B, L, G, N)).astype(np.float32)
    C = rng.normal(size=(B, L, G, N)).astype(np.float32)
    yc, hc = ref.ssd_scan_chunked(x, dt, A, Bm, C, chunk=32)
    ys, hs = ref.ssd_scan(x, dt, A, Bm, C)
    np.testing.assert_allclose(np.asarray(yc), np.asarray(ys),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(hc), np.asarray(hs),
                               rtol=2e-3, atol=2e-3)
