"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.conv2d_gemm import conv2d_gemm
from repro.kernels.flash_attention import flash_attention
from repro.kernels.hough_vote import compact_edges, hough_vote
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.tiled_matmul import tiled_matmul


@pytest.mark.parametrize("m,k,n", [(8, 16, 8), (100, 70, 50), (128, 128, 128),
                                   (33, 129, 65)])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_tiled_matmul_float(rng, m, k, n, dtype):
    x = rng.normal(size=(m, k)).astype(np.float32)
    y = rng.normal(size=(k, n)).astype(np.float32)
    x = jnp.asarray(x, dtype)
    y = jnp.asarray(y, dtype)
    got = tiled_matmul(x, y, interpret=True, bm=32, bn=32, bk=32)
    want = ref.tiled_matmul(x, y)
    tol = 1e-5 if dtype == "float32" else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


@pytest.mark.parametrize("m,k,n", [(16, 32, 16), (64, 48, 32)])
def test_tiled_matmul_int8(rng, m, k, n):
    x = rng.integers(-127, 127, (m, k), dtype=np.int8)
    y = rng.integers(-127, 127, (k, n), dtype=np.int8)
    got = tiled_matmul(jnp.asarray(x), jnp.asarray(y), interpret=True,
                       bm=16, bn=16, bk=16)
    want = ref.tiled_matmul(jnp.asarray(x), jnp.asarray(y))
    assert got.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("hw", [(16, 24), (37, 52), (64, 64)])
@pytest.mark.parametrize("masks", [(1, 3, 3), (3, 5, 5), (3, 7, 7)])
def test_conv2d_gemm_float(rng, hw, masks):
    H, W = hw
    img = rng.normal(size=(H, W)).astype(np.float32)
    m = rng.normal(size=masks).astype(np.float32)
    got = conv2d_gemm(jnp.asarray(img), jnp.asarray(m), interpret=True, bh=8)
    want = ref.conv2d_gemm(jnp.asarray(img), jnp.asarray(m))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_conv2d_gemm_int(rng):
    img = rng.integers(0, 255, (40, 56)).astype(np.int32)
    m = rng.integers(-16, 16, (3, 5, 5)).astype(np.int32)
    got = conv2d_gemm(jnp.asarray(img), jnp.asarray(m), interpret=True, bh=8)
    want = ref.conv2d_gemm(jnp.asarray(img), jnp.asarray(m))
    assert got.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("hw,bh,bw", [((21, 19), 8, 8), ((37, 52), 8, 16)])
def test_conv2d_gemm_halo_non_multiple(rng, hw, bh, bw):
    """Halo-tiled grid on shapes that do not divide the block sizes."""
    img = rng.normal(size=hw).astype(np.float32)
    m = rng.normal(size=(3, 7, 7)).astype(np.float32)
    got = conv2d_gemm(jnp.asarray(img), jnp.asarray(m), interpret=True,
                      bh=bh, bw=bw)
    want = ref.conv2d_gemm(jnp.asarray(img), jnp.asarray(m))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_conv2d_gemm_batched_one_launch(rng):
    """(N, H, W) lowers with a leading batch grid axis == per-frame loop."""
    imgs = rng.normal(size=(3, 21, 37)).astype(np.float32)
    m = rng.normal(size=(3, 5, 5)).astype(np.float32)
    got = conv2d_gemm(jnp.asarray(imgs), jnp.asarray(m), interpret=True,
                      bh=8, bw=16)
    assert got.shape == (3, 3, 21, 37)
    for i in range(3):
        want = conv2d_gemm(jnp.asarray(imgs[i]), jnp.asarray(m),
                           interpret=True, bh=8, bw=16)
        np.testing.assert_array_equal(np.asarray(got[i]), np.asarray(want))
    wantb = ref.conv2d_gemm(jnp.asarray(imgs), jnp.asarray(m))
    np.testing.assert_allclose(np.asarray(got), np.asarray(wantb),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n_pix,n_theta,n_rho", [(64, 45, 60), (200, 180, 150)])
def test_hough_vote(rng, n_pix, n_theta, n_rho):
    xy = rng.uniform(0, 40, (n_pix, 3)).astype(np.float32)
    xy[:, 2] = 1.0
    w = (rng.uniform(size=n_pix) > 0.4).astype(np.float32)
    trig = rng.uniform(-1, 1, (3, n_theta)).astype(np.float32)
    trig[2] = n_rho / 2.5
    got = hough_vote(jnp.asarray(xy), jnp.asarray(w), jnp.asarray(trig),
                     n_rho=n_rho, interpret=True, br=32, bp=64)
    want = ref.hough_vote(jnp.asarray(xy), jnp.asarray(w), jnp.asarray(trig),
                          n_rho=n_rho)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3)


def _vote_inputs(rng, n_pix, n_theta, n_rho, edge_frac=0.15, batch=None):
    xy = rng.uniform(0, 40, (n_pix, 3)).astype(np.float32)
    xy[:, 2] = 1.0
    shape = (batch, n_pix) if batch else (n_pix,)
    w = (rng.uniform(size=shape) > 1 - edge_frac).astype(np.float32)
    trig = rng.uniform(-1, 1, (3, n_theta)).astype(np.float32)
    trig[2] = n_rho / 2.5
    return jnp.asarray(xy), jnp.asarray(w), jnp.asarray(trig)


def test_hough_vote_batched(rng):
    """Shared raster coords + (N, P) weights lower as one batched kernel."""
    xy, w, trig = _vote_inputs(rng, 200, 90, 150, edge_frac=0.3, batch=3)
    got = hough_vote(xy, w, trig, n_rho=150, interpret=True,
                     br=32, bp=64, bt=32)
    assert got.shape == (3, 150, 90)
    want = ref.hough_vote(xy, w, trig, n_rho=150)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3)


def test_compact_edges_matches_ref(rng):
    """Prefix-sum-scatter compaction == stable-sort oracle, single + batch."""
    from repro.kernels.hough_vote import compact_edges
    xy, w, _ = _vote_inputs(rng, 200, 45, 60)
    for weights in (w, jnp.stack([w, jnp.roll(w, 7)])):
        cxy1, cw1 = compact_edges(xy, weights, max_edges=64)
        cxy2, cw2 = ref.compact_edges(xy, weights, max_edges=64)
        np.testing.assert_array_equal(np.asarray(cxy1), np.asarray(cxy2))
        np.testing.assert_array_equal(np.asarray(cw1), np.asarray(cw2))


@pytest.mark.parametrize("impl", ["xla", "interpret"])
def test_hough_vote_compact_parity(rng, impl):
    """Compacted voting == dense voting == ref oracle, for both impls, and
    the compacted kernel's pixel iteration is bounded by max_edges."""
    from repro.kernels import ops
    from repro.kernels.hough_vote import compact_edges
    max_edges = 64
    xy, _, trig = _vote_inputs(rng, 400, 45, 80)
    wn = np.zeros(400, np.float32)
    wn[rng.choice(400, 50, replace=False)] = 1.0  # 50 edges < max_edges
    w = jnp.asarray(wn)
    dense = ref.hough_vote(xy, w, trig, n_rho=80)
    got = ops.hough_vote(xy, w, trig, n_rho=80, impl=impl, compact=True,
                         max_edges=max_edges)
    # vote counts are small integers in f32: compaction must be *exact*
    np.testing.assert_array_equal(np.asarray(got), np.asarray(dense))
    np.testing.assert_array_equal(
        np.asarray(got),
        np.asarray(ref.hough_vote_compact(xy, w, trig, n_rho=80,
                                          max_edges=max_edges)),
    )
    # the compacted pixel set — what the vote grid iterates — is static
    # max_edges, not n_pix
    cxy, cw = compact_edges(xy, w, max_edges=max_edges)
    assert cxy.shape == (max_edges, 3) and cw.shape == (max_edges,)
    assert int((w > 0).sum()) <= max_edges  # no drops in this sweep


def test_compact_edges_overflow_drops(rng):
    """Edges past max_edges are dropped, never scattered out of bounds."""
    from repro.kernels.hough_vote import compact_edges
    xy, _, _ = _vote_inputs(rng, 100, 45, 60)
    w = jnp.ones((100,), jnp.float32)  # every pixel is an edge
    cxy, cw = compact_edges(xy, w, max_edges=16)
    assert cxy.shape == (16, 3)
    np.testing.assert_array_equal(np.asarray(cw), np.ones(16, np.float32))
    np.testing.assert_array_equal(np.asarray(cxy), np.asarray(xy)[:16])


# Deterministic twins of the hypothesis properties in test_properties.py
# (that module is skipped wholesale when hypothesis isn't installed, so the
# invariants the compaction fast path rests on are pinned here too).


@pytest.mark.parametrize("seed,density,max_edges",
                         [(0, 1, 16), (1, 5, 64), (2, 9, 64), (3, 3, 96)])
def test_compact_edges_stable_prefix(seed, density, max_edges):
    """Compaction output is exactly the first max_edges edge rows in
    original index order (no permutation, no fabrication), zero-padded."""
    rng2 = np.random.default_rng(seed)
    n_pix = 128
    w = (rng2.uniform(size=n_pix) < density / 10.0).astype(np.float32)
    xy = np.stack([np.arange(n_pix), np.arange(n_pix) * 2,
                   np.ones(n_pix)], axis=1).astype(np.float32)
    idx = np.flatnonzero(w > 0)[:max_edges]
    want_xy = np.zeros((max_edges, 3), np.float32)
    want_w = np.zeros(max_edges, np.float32)
    want_xy[: len(idx)] = xy[idx]
    want_w[: len(idx)] = w[idx]
    for impl in (compact_edges, ref.compact_edges):
        cxy, cw = impl(jnp.asarray(xy), jnp.asarray(w), max_edges=max_edges)
        np.testing.assert_array_equal(np.asarray(cxy), want_xy)
        np.testing.assert_array_equal(np.asarray(cw), want_w)


@pytest.mark.parametrize("seed,density", [(0, 1), (1, 3), (2, 6)])
def test_compacted_vote_bit_exact_when_buffer_fits(seed, density):
    """n_edges <= max_edges => compacted accumulator == dense, bit-exact."""
    from repro.kernels import ops
    rng2 = np.random.default_rng(seed)
    n_pix, n_theta, n_rho = 300, 45, 80
    xy = jnp.asarray(
        np.stack([rng2.uniform(0, 30, n_pix), rng2.uniform(0, 30, n_pix),
                  np.ones(n_pix)], axis=1).astype(np.float32))
    w = jnp.asarray(
        (rng2.uniform(size=n_pix) < density / 10.0).astype(np.float32))
    theta = np.arange(n_theta) * (np.pi / n_theta)
    trig = jnp.asarray(np.stack([
        np.cos(theta), np.sin(theta), np.full_like(theta, 43.0),
    ]).astype(np.float32))
    max_edges = max(8, int(np.asarray(w > 0).sum()))
    dense = ops.hough_vote(xy, w, trig, n_rho=n_rho, impl="xla")
    compact = ops.hough_vote(xy, w, trig, n_rho=n_rho, impl="xla",
                             compact=True, max_edges=max_edges)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(compact))


@pytest.mark.parametrize("gqa", [1, 2, 4])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 24),
                                           (False, None)])
def test_flash_attention(rng, gqa, causal, window):
    B, Hq, L, D = 2, 4, 72, 16
    q = rng.normal(size=(B, Hq, L, D)).astype(np.float32)
    k = rng.normal(size=(B, Hq // gqa, L, D)).astype(np.float32)
    v = rng.normal(size=(B, Hq // gqa, L, D)).astype(np.float32)
    got = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                          causal=causal, window=window, interpret=True,
                          bq=16, bk=16)
    want = ref.attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                         causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_decode_offset(rng):
    """Decode: 1 query at the end of a long kv timeline."""
    B, H, Lkv, D = 2, 4, 96, 16
    q = rng.normal(size=(B, H, 1, D)).astype(np.float32)
    k = rng.normal(size=(B, H, Lkv, D)).astype(np.float32)
    v = rng.normal(size=(B, H, Lkv, D)).astype(np.float32)
    got = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                          causal=True, q_offset=Lkv - 1, interpret=True,
                          bq=8, bk=32)
    want = ref.attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                         causal=True, q_offset=Lkv - 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_attention_blockwise_matches_dense_and_grads(rng):
    B, Hq, L, D = 2, 4, 50, 16
    q = jnp.asarray(rng.normal(size=(B, Hq, L, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, 2, L, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, 2, L, D)), jnp.float32)

    out_b = ref.attention_blockwise(q, k, v, causal=True, window=17, block=16)
    out_d = ref.attention(q, k, v, causal=True, window=17)
    np.testing.assert_allclose(np.asarray(out_b), np.asarray(out_d),
                               rtol=1e-4, atol=1e-4)

    def lb(q, k, v):
        return jnp.sum(jnp.sin(ref.attention_blockwise(
            q, k, v, causal=True, window=17, block=16) * 3))

    def ld(q, k, v):
        return jnp.sum(jnp.sin(ref.attention(
            q, k, v, causal=True, window=17) * 3))

    gb = jax.grad(lb, argnums=(0, 1, 2))(q, k, v)
    gd = jax.grad(ld, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gb, gd):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("G", [1, 2])
@pytest.mark.parametrize("chunk", [16, 32])
def test_ssd_scan(rng, G, chunk):
    B, L, H, P, N = 2, 80, 4, 16, 8
    x = (rng.normal(size=(B, L, H, P)) * 0.1).astype(np.float32)
    dt = rng.uniform(0.01, 0.1, (B, L, H)).astype(np.float32)
    A = -rng.uniform(0.5, 1.5, (H,)).astype(np.float32)
    Bm = rng.normal(size=(B, L, G, N)).astype(np.float32)
    C = rng.normal(size=(B, L, G, N)).astype(np.float32)
    ya, sa = ssd_scan(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(A),
                      jnp.asarray(Bm), jnp.asarray(C), chunk=chunk,
                      interpret=True)
    yb, sb = ref.ssd_scan(x, dt, A, Bm, C)
    np.testing.assert_allclose(np.asarray(ya), np.asarray(yb),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(sa), np.asarray(sb),
                               rtol=2e-3, atol=2e-3)


def test_ssd_chunked_ref_matches_sequential(rng):
    B, L, H, P, N, G = 2, 100, 4, 16, 8, 2
    x = (rng.normal(size=(B, L, H, P)) * 0.1).astype(np.float32)
    dt = rng.uniform(0.01, 0.1, (B, L, H)).astype(np.float32)
    A = -rng.uniform(0.5, 1.5, (H,)).astype(np.float32)
    Bm = rng.normal(size=(B, L, G, N)).astype(np.float32)
    C = rng.normal(size=(B, L, G, N)).astype(np.float32)
    yc, hc = ref.ssd_scan_chunked(x, dt, A, Bm, C, chunk=32)
    ys, hs = ref.ssd_scan(x, dt, A, Bm, C)
    np.testing.assert_allclose(np.asarray(yc), np.asarray(ys),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(hc), np.asarray(hs),
                               rtol=2e-3, atol=2e-3)
