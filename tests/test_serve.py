"""Serving engine: continuous batching, admission, cache consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import build
from repro.serve import Engine, Request
from repro.serve.sampling import sample


@pytest.fixture(scope="module")
def dense_model():
    cfg = get_smoke("yi-9b")
    m = build(cfg)
    return m, m.init(jax.random.PRNGKey(0))


def test_sampling_greedy_and_topk(rng):
    logits = jnp.asarray(rng.normal(size=(3, 50)), jnp.float32)
    g = sample(jax.random.PRNGKey(0), logits, temperature=0.0)
    np.testing.assert_array_equal(np.asarray(g),
                                  np.asarray(jnp.argmax(logits, -1)))
    t = sample(jax.random.PRNGKey(0), logits, temperature=0.7, top_k=5)
    top5 = np.argsort(np.asarray(logits), axis=-1)[:, -5:]
    for i in range(3):
        assert int(t[i]) in top5[i]


def test_engine_matches_manual_decode(dense_model):
    """Engine greedy continuation == manual per-token decode (logit-exact)."""
    m, params = dense_model
    prompt = [3, 7, 11, 2, 9]
    eng = Engine(m, params, n_slots=2, max_len=32, prefill_buckets=(4, 8))
    req = Request(uid=0, prompt=list(prompt), max_new_tokens=6)
    eng.submit(req)
    eng.run()

    cache = m.init_cache(1, 32)
    toks = list(prompt)
    out = []
    for t in range(len(prompt) + 5):
        tok = toks[t] if t < len(toks) else out[-1]
        lg, cache = m.decode_step(
            params, jnp.asarray([tok], jnp.int32), cache,
            jnp.asarray([t], jnp.int32))
        if t >= len(prompt) - 1:
            out.append(int(jnp.argmax(lg[0])))
    assert req.output == out


def test_engine_continuous_batching(dense_model):
    """More requests than slots: all finish, slots reused, different lengths."""
    m, params = dense_model
    eng = Engine(m, params, n_slots=2, max_len=64, prefill_buckets=(4, 8, 16))
    reqs = [
        Request(uid=i, prompt=list(range(1, 3 + i)), max_new_tokens=3 + i)
        for i in range(5)
    ]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done for r in reqs)
    for i, r in enumerate(reqs):
        assert len(r.output) == 3 + i
    assert eng.active == 0 and not eng.queue


def test_engine_eos_stops(dense_model):
    m, params = dense_model
    # find what the model actually emits, then use it as eos
    probe = Request(uid=0, prompt=[1, 2, 3], max_new_tokens=4)
    eng = Engine(m, params, n_slots=1, max_len=32)
    eng.submit(probe)
    eng.run()
    eos = probe.output[0]
    eng2 = Engine(m, params, n_slots=1, max_len=32)
    r = Request(uid=1, prompt=[1, 2, 3], max_new_tokens=50, eos_id=eos)
    eng2.submit(r)
    eng2.run()
    assert r.done and r.output[-1] == eos and len(r.output) < 50


def test_engine_ssm_exact_prefill():
    """SSM families admit at exact length (recurrent state can't pad)."""
    cfg = get_smoke("falcon-mamba-7b")
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = Engine(m, params, n_slots=2, max_len=32)
    reqs = [Request(uid=i, prompt=[5, 6, 7, 8, 9][: 3 + i],
                    max_new_tokens=4) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done and len(r.output) == 4 for r in reqs)
