"""QoS layer of the detection service, on a virtual clock.

Every test here drives ``DetectionService`` with an injected
:class:`VirtualClock`: deadlines, backpressure, EDF ordering, and early
batch close are decided on virtual time, so no assertion depends on wall
clock, sleeps, or host load (the bench host is a noisy 2-core box).  The
throughput-mode fallback must stay bit-identical to the PR-3 scheduler,
and the prefetch-threaded staging path must match synchronous staging
bit-for-bit.
"""

import dataclasses
import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import LineDetector, HoughConfig, PipelineConfig
from repro.core.plan import load_frame
from repro.serve.detection import (
    DetectionRequest, DetectionService, PrefetchStager, RequestStatus,
    VirtualClock, crop_result, pad_to_bucket,
)

pytestmark = pytest.mark.deadline

BUCKETS = ((96, 128), (120, 160))


def _cfg() -> PipelineConfig:
    return PipelineConfig(hough=HoughConfig(compact=True, max_edges="auto"))


def make_svc(**kw) -> DetectionService:
    kw.setdefault("buckets", BUCKETS)
    kw.setdefault("batch_size", 2)
    kw.setdefault("clock", VirtualClock())
    kw.setdefault("prefetch", False)   # thread coverage is explicit below
    return DetectionService(_cfg(), **kw)


def _frame(h: int, w: int, seed: int = 0) -> np.ndarray:
    from repro.data import make_scenario
    return make_scenario("straight", h, w, seed=seed).image


# --- virtual clock ----------------------------------------------------------


def test_virtual_clock_is_deterministic():
    clock = VirtualClock()
    assert clock() == 0.0
    clock.advance(0.25)
    clock.advance(0.0)
    assert clock() == 0.25
    with pytest.raises(AssertionError):
        clock.advance(-1.0)


# --- EDF ordering -----------------------------------------------------------


def test_edf_ordering_within_bucket():
    """Four requests, two slots: the two *earliest deadlines* dispatch in
    the first wave regardless of arrival order."""
    svc = make_svc(buckets=((96, 128),), est_dispatch_s=0.0)
    deadlines = [4.0, 1.0, 3.0, 2.0]
    reqs = [DetectionRequest(uid=i, frame=_frame(96, 128, seed=i),
                             deadline_s=d)
            for i, d in enumerate(deadlines)]
    for r in reqs:
        assert svc.submit(r) is RequestStatus.PENDING
    assert svc.step()          # admits EDF, grid full, dispatches
    svc.drain()
    first_wave = {r.uid for r in reqs if r.done}
    assert first_wave == {1, 3}          # deadlines 1.0 and 2.0
    svc.run()
    assert all(r.ok for r in reqs)
    assert svc.completed == 4 and svc.completed_late == 0


def test_priority_breaks_deadline_ties():
    svc = make_svc(buckets=((96, 128),), batch_size=1)
    r_lo = DetectionRequest(uid=0, frame=_frame(96, 128, seed=0),
                            deadline_s=1.0, priority=5)
    r_hi = DetectionRequest(uid=1, frame=_frame(96, 128, seed=1),
                            deadline_s=1.0, priority=0)
    svc.submit(r_lo)
    svc.submit(r_hi)           # same deadline_at (clock never moved)
    svc.step()
    svc.drain()
    assert r_hi.done and not r_lo.done   # lower priority value goes first
    svc.run()
    assert r_lo.ok


def test_no_deadlines_means_throughput_mode_bit_exact():
    """With no deadlines set (or with uniformly slack ones) the scheduler
    is the PR-3 full-grid-first path: identical traffic must produce
    bit-identical results and the same dispatch composition (EDF may order
    grids differently on ties, but the batches it forms are the same)."""
    shapes = [(96, 128), (120, 160), (80, 100), (96, 128),
              (100, 144), (120, 160)]
    frames = [_frame(h, w, seed=i) for i, (h, w) in enumerate(shapes)]

    plain = make_svc()
    reqs_plain = plain.detect_many(frames)

    slack = make_svc()
    reqs_slack = [DetectionRequest(uid=i, frame=f, deadline_s=1000.0)
                  for i, f in enumerate(frames)]
    for r in reqs_slack:
        slack.submit(r)
    slack.run()

    assert sorted((s, n) for s, n, _ in plain.dispatch_log) == \
        sorted((s, n) for s, n, _ in slack.dispatch_log)
    for a, b in zip(reqs_plain, reqs_slack):
        assert b.ok and not b.missed_deadline
        np.testing.assert_array_equal(np.asarray(a.result.lines),
                                      np.asarray(b.result.lines))
        np.testing.assert_array_equal(np.asarray(a.result.valid),
                                      np.asarray(b.result.valid))
        np.testing.assert_array_equal(np.asarray(a.result.peaks),
                                      np.asarray(b.result.peaks))
        np.testing.assert_array_equal(np.asarray(a.result.edges),
                                      np.asarray(b.result.edges))


# --- early batch close ------------------------------------------------------


def test_early_batch_close_on_tight_deadline():
    """A lone request in a 4-slot grid waits while its deadline has slack,
    then closes the batch early (partial dispatch) once one more wait
    would bust it — decided purely on the virtual clock."""
    clock = VirtualClock()
    svc = make_svc(buckets=((96, 128),), batch_size=4, clock=clock,
                   est_dispatch_s=0.05)
    req = DetectionRequest(uid=0, frame=_frame(96, 128), deadline_s=0.1)
    svc.submit(req)
    assert svc.step()                    # slack 0.1 > est 0.05: hold
    assert svc.dispatches == 0
    assert svc.grids[(96, 128)].active == 1      # admitted, waiting
    clock.advance(0.06)                  # slack 0.04 <= est 0.05: close
    assert svc.step()
    assert svc.dispatches == 1
    assert svc.dispatch_log[-1] == ((96, 128), 1, False)
    svc.drain()
    assert req.ok and not req.missed_deadline
    assert svc.completed_late == 0


def test_full_grid_never_waits_without_deadlines():
    svc = make_svc(buckets=((96, 128),), batch_size=2)
    for i in range(2):
        svc.submit(DetectionRequest(uid=i, frame=_frame(96, 128, seed=i)))
    assert svc.step()
    assert svc.dispatches == 1           # full grid dispatches immediately


def test_less_urgent_full_grid_yields_to_tight_deadline():
    """EDF admission control: a full no-deadline grid only jumps ahead of
    a waiting deadlined grid when its dispatch fits in that grid's slack."""
    def build(deadline_s):
        clock = VirtualClock()
        svc = make_svc(clock=clock, est_dispatch_s=0.05)
        svc.submit(DetectionRequest(uid=0, frame=_frame(96, 128),
                                    deadline_s=deadline_s))
        for i in (1, 2):                 # fill the (120,160) grid
            svc.submit(DetectionRequest(uid=i,
                                        frame=_frame(120, 160, seed=i)))
        return clock, svc

    # tight: dispatching the full grid first (est 0.05) would leave
    # 0.08 - 0.05 = 0.03 < est of the deadlined grid -> hold everything
    clock, svc = build(0.08)
    assert svc.step()
    assert svc.dispatches == 0
    clock.advance(0.04)                  # now the deadlined grid is urgent
    assert svc.step()
    assert svc.dispatch_log[-1] == ((96, 128), 1, False)
    svc.run()

    # slack: the full grid fits inside the 0.5s budget -> throughput flows
    _, svc = build(0.5)
    assert svc.step()
    assert svc.dispatch_log[-1] == ((120, 160), 2, False)
    svc.run()


# --- backpressure + shedding ------------------------------------------------


def test_queue_full_rejects_with_explicit_status():
    svc = make_svc(buckets=((96, 128),), batch_size=1, max_queue=2)
    reqs = [DetectionRequest(uid=i, frame=_frame(96, 128, seed=i),
                             deadline_s=1.0)
            for i in range(4)]
    statuses = [svc.submit(r) for r in reqs]
    assert statuses[:2] == [RequestStatus.PENDING, RequestStatus.PENDING]
    assert statuses[2:] == [RequestStatus.QUEUE_FULL,
                            RequestStatus.QUEUE_FULL]
    assert svc.rejected_queue_full == 2
    for r in reqs[2:]:
        assert r.done and r.result is None and r.missed_deadline
    svc.run()
    assert all(r.ok for r in reqs[:2])
    # queue capacity freed by admission: new submits are accepted again
    late = DetectionRequest(uid=9, frame=_frame(96, 128))
    assert svc.submit(late) is RequestStatus.PENDING
    svc.run()
    assert late.ok


def test_expired_requests_are_shed_not_run():
    clock = VirtualClock()
    svc = make_svc(clock=clock)
    req = DetectionRequest(uid=0, frame=_frame(96, 128), deadline_s=0.05)
    svc.submit(req)
    clock.advance(0.1)                   # deadline passed while queued
    svc.run()
    assert req.status is RequestStatus.DEADLINE_EXCEEDED
    assert req.done and req.result is None and req.missed_deadline
    assert svc.shed_deadline == 1 and svc.dispatches == 0


def test_hopeless_requests_are_shed_at_admission():
    """Admission control: once the service-time estimate is *measured*, a
    queued request whose remaining budget is below it is shed before it
    wastes a slot — even though its deadline has not passed yet."""
    clock = VirtualClock()
    svc = make_svc(buckets=((96, 128),), batch_size=1, clock=clock,
                   est_dispatch_s=0.05)
    # ground the estimate: the first (compiling) dispatch never measures,
    # so dispatch 2's completion — 0.05s of virtual time after it was
    # issued, at or below the 0.05 prior, so every completion path accepts
    # the sample — grounds the EMA at 0.05
    warms = [DetectionRequest(uid=u, frame=_frame(96, 128, seed=u))
             for u in (7, 8, 9)]
    for w in warms:
        svc.submit(w)
        svc.step()
        clock.advance(0.05)
    svc.drain()
    assert all(w.ok for w in warms)
    assert svc.grids[(96, 128)].est_measured

    doomed = DetectionRequest(uid=0, frame=_frame(96, 128),
                              deadline_s=0.02)    # < est: cannot make it
    ok = DetectionRequest(uid=1, frame=_frame(96, 128, seed=1),
                          deadline_s=0.2)
    svc.submit(doomed)
    svc.submit(ok)
    svc.run()
    assert doomed.status is RequestStatus.DEADLINE_EXCEEDED
    assert doomed.result is None and svc.shed_deadline == 1
    assert ok.ok and not ok.missed_deadline


def _ground_estimate(svc, clock, shape=(96, 128), dt=0.05):
    """Feed three warm dispatches at a fixed virtual cost so the bucket's
    service-time EMA is *measured* at ``dt`` (same recipe as above: the
    compiling dispatch never samples; the later completions do)."""
    warms = [DetectionRequest(uid=900 + u, frame=_frame(*shape, seed=u))
             for u in range(3)]
    for w in warms:
        svc.submit(w)
        svc.step()
        clock.advance(dt)
    svc.drain()
    assert all(w.ok for w in warms)
    assert svc.grids[shape].est_measured


def test_queue_depth_aware_shed_deep_queue():
    """The PR-4 follow-up closed: feasibility counts everything AHEAD in
    EDF order (batch_size per wave), not one optimistic dispatch.  Three
    equal budgets of 2.4x the measured per-dispatch cost on a 1-slot
    grid: positions 0 and 1 can finish inside budget (1 and 2 waves),
    position 2 needs 3 waves -> hopeless, shed immediately."""
    clock = VirtualClock()
    svc = make_svc(buckets=((96, 128),), batch_size=1, clock=clock,
                   est_dispatch_s=0.05)
    _ground_estimate(svc, clock)
    reqs = [DetectionRequest(uid=i, frame=_frame(96, 128, seed=i),
                             deadline_s=0.12)
            for i in range(3)]
    for r in reqs:
        svc.submit(r)
    svc.run()
    assert reqs[0].ok and reqs[1].ok
    assert reqs[2].status is RequestStatus.DEADLINE_EXCEEDED
    assert svc.shed_deadline == 1


def test_queue_depth_shed_shallow_queue_unchanged():
    """A shallow queue reduces to the old single-dispatch rule: the same
    0.12 budget that a deep queue sheds survives alone, and a budget
    below one dispatch is still shed."""
    clock = VirtualClock()
    svc = make_svc(buckets=((96, 128),), batch_size=1, clock=clock,
                   est_dispatch_s=0.05)
    _ground_estimate(svc, clock)
    lone = DetectionRequest(uid=0, frame=_frame(96, 128), deadline_s=0.12)
    svc.submit(lone)
    svc.run()
    assert lone.ok and svc.shed_deadline == 0
    doomed = DetectionRequest(uid=1, frame=_frame(96, 128),
                              deadline_s=0.03)   # < one dispatch
    svc.submit(doomed)
    svc.run()
    assert doomed.status is RequestStatus.DEADLINE_EXCEEDED
    assert svc.shed_deadline == 1


def test_queue_depth_shed_counts_occupied_slots():
    """Slotted-but-undispatched work occupies the first wave: with one
    slot already taken on a 2-slot grid, the 2nd queued deadline needs a
    2nd wave and sheds — the identical queue on an empty grid survives."""
    def drive(pre_occupy: bool):
        clock = VirtualClock()
        svc = make_svc(buckets=((96, 128),), batch_size=2, clock=clock,
                       est_dispatch_s=0.05)
        # ground the EMA with two full grids (batch_size=2)
        w = [DetectionRequest(uid=900 + u, frame=_frame(96, 128, seed=u))
             for u in range(4)]
        for a, b in ((w[0], w[1]), (w[2], w[3])):
            svc.submit(a)
            svc.submit(b)
            svc.step()
            clock.advance(0.05)
        svc.drain()
        assert svc.grids[(96, 128)].est_measured
        if pre_occupy:
            svc.submit(DetectionRequest(uid=50, frame=_frame(96, 128)))
            svc.step()                      # slots it; partial grid waits
            assert svc.grids[(96, 128)].active == 1
        d = [DetectionRequest(uid=i, frame=_frame(96, 128, seed=i),
                              deadline_s=0.08)
             for i in range(2)]
        for r in d:
            svc.submit(r)
        svc.run()
        return d, svc
    d, svc = drive(pre_occupy=True)
    assert d[0].ok
    assert d[1].status is RequestStatus.DEADLINE_EXCEEDED
    assert svc.shed_deadline == 1
    d, svc = drive(pre_occupy=False)
    assert d[0].ok and d[1].ok and svc.shed_deadline == 0


def test_no_deadline_requests_never_shed_and_do_not_inflate():
    """inf-keyed entries sort last in EDF order: they cannot push a
    deadlined request into an extra wave, and are never shed no matter
    how deep the queue."""
    clock = VirtualClock()
    svc = make_svc(buckets=((96, 128),), batch_size=1, clock=clock,
                   est_dispatch_s=0.05)
    _ground_estimate(svc, clock)
    plain = [DetectionRequest(uid=10 + i, frame=_frame(96, 128, seed=i))
             for i in range(4)]
    tight = DetectionRequest(uid=0, frame=_frame(96, 128), deadline_s=0.06)
    for r in plain[:2]:
        svc.submit(r)
    svc.submit(tight)       # EDF puts it ahead of every no-deadline entry
    for r in plain[2:]:
        svc.submit(r)
    svc.run()
    assert tight.ok and all(r.ok for r in plain)
    assert svc.shed_deadline == 0


# --- session-stateful streaming ---------------------------------------------


def test_session_tracker_advances_in_stream_order():
    """Frames sharing a session_id advance one LaneTracker in submit
    order across dispatches: hits grow monotonically, the lane confirms,
    and the smoothed tracks ride on each request; sessionless requests
    get none."""
    svc = make_svc(buckets=((96, 128),), batch_size=2)
    frame = _frame(96, 128, seed=0)
    reqs = [DetectionRequest(uid=i, frame=frame, session_id="cam0")
            for i in range(6)]
    loner = DetectionRequest(uid=99, frame=frame)
    for r in reqs:
        svc.submit(r)
    svc.submit(loner)
    svc.run()
    assert all(r.ok for r in reqs) and loner.ok
    assert loner.tracks is None
    hits = [max(t.hits for t in r.tracks) for r in reqs]
    assert hits == [1, 2, 3, 4, 5, 6]      # stream order, no reordering
    assert not reqs[0].tracks[0].confirmed
    assert all(t.confirmed for t in reqs[-1].tracks)
    # the static scene's doublets merge: one track per planted lane
    assert len(reqs[-1].tracks) == 2
    assert len(svc.session_tracks("cam0")) == 2
    svc.end_session("cam0")
    assert svc.session_tracks("cam0") == []


def test_sessions_are_isolated():
    svc = make_svc(buckets=((96, 128),), batch_size=2)
    fa, fb = _frame(96, 128, seed=0), _frame(96, 128, seed=3)
    reqs = []
    for i in range(4):
        reqs.append(DetectionRequest(uid=2 * i, frame=fa, session_id="a"))
        reqs.append(DetectionRequest(uid=2 * i + 1, frame=fb,
                                     session_id="b"))
    for r in reqs:
        svc.submit(r)
    svc.run()
    assert all(r.ok for r in reqs)
    ta = {t.track_id for t in svc.session_tracks("a")}
    tb = {t.track_id for t in svc.session_tracks("b")}
    assert len(ta) == 2 and len(tb) == 2   # independent id spaces
    a_last = [r for r in reqs if r.session_id == "a"][-1]
    assert max(t.hits for t in a_last.tracks) == 4


def test_unmeasured_estimate_never_latches_into_shedding():
    """Before any dispatch has grounded the estimate, a sub-estimate
    budget is NOT shed: an inflated prior must not lock the service into
    refusing feasible work forever (the estimate only corrects on
    completions, so shedding everything would never recover)."""
    svc = make_svc(buckets=((96, 128),), batch_size=1,
                   est_dispatch_s=10.0)           # absurd prior
    req = DetectionRequest(uid=0, frame=_frame(96, 128), deadline_s=0.5)
    svc.submit(req)
    svc.run()
    assert req.ok and svc.shed_deadline == 0


def test_completed_late_is_counted_not_hidden():
    clock = VirtualClock()
    svc = make_svc(buckets=((96, 128),), batch_size=1, clock=clock)
    req = DetectionRequest(uid=0, frame=_frame(96, 128), deadline_s=0.05)
    svc.submit(req)
    svc.step()                           # full 1-slot grid dispatches at t=0
    clock.advance(0.1)                   # "compute" outlives the deadline
    svc.drain()
    assert req.ok and req.missed_deadline
    assert svc.completed_late == 1 and svc.shed_deadline == 0


def test_zero_misses_when_deadlines_are_slack():
    clock = VirtualClock()
    svc = make_svc(clock=clock)
    shapes = [(96, 128), (120, 160)] * 4
    reqs = [DetectionRequest(uid=i, frame=_frame(h, w, seed=i),
                             deadline_s=100.0)
            for i, (h, w) in enumerate(shapes)]
    for r in reqs:
        svc.submit(r)
        clock.advance(0.001)
        svc.step()
    svc.run()
    assert all(r.ok and not r.missed_deadline for r in reqs)
    assert svc.shed_deadline == 0 == svc.completed_late
    assert svc.rejected_queue_full == 0


# --- per-request render_output ----------------------------------------------


@pytest.mark.parametrize("shape,bucket",
                         [((80, 100), (96, 128)), ((100, 144), (120, 160)),
                          ((96, 128), (96, 128))])
def test_render_output_round_trip_per_bucket(shape, bucket):
    """The per-request overlay equals the unbatched render path on the
    padded frame, cropped back bit-exact; outside the detected lines every
    pixel is the native frame (no pad pixels survive the crop)."""
    svc = make_svc()
    req = DetectionRequest(uid=0, frame=_frame(*shape),
                           render_output=True)
    svc.submit(req)
    svc.run()
    assert req.bucket == bucket
    rend = np.asarray(req.result.rendered)
    assert rend.shape == (*shape, 3)

    det = LineDetector(dataclasses.replace(_cfg(), render_output=True))
    padded = pad_to_bucket(req.frame, bucket)
    ref = crop_result(det.detect(jnp.asarray(padded)), *shape)
    np.testing.assert_array_equal(rend, np.asarray(ref.rendered))

    base = load_frame(req.frame).astype(np.uint8)
    line_px = ((rend[..., 0] == 255) & (rend[..., 1] == 0)
               & (rend[..., 2] == 0))
    assert line_px.any()                 # the overlay actually drew lines
    for c in range(3):
        np.testing.assert_array_equal(rend[..., c][~line_px],
                                      base[~line_px])


def test_render_binding_is_per_request_within_a_grid():
    """One grid, one request asking for the overlay: only that request
    gets ``rendered``; detection outputs are unchanged by the render
    binding (same values as a render-free service run)."""
    frames = [_frame(96, 128, seed=7), _frame(96, 128, seed=8)]
    svc = make_svc(buckets=((96, 128),))
    reqs = [
        DetectionRequest(uid=0, frame=frames[0], render_output=True),
        DetectionRequest(uid=1, frame=frames[1]),
    ]
    for r in reqs:
        svc.submit(r)
    svc.run()
    assert svc.dispatch_log[-1] == ((96, 128), 2, True)
    assert reqs[0].result.rendered is not None
    assert reqs[1].result.rendered is None

    plain = make_svc(buckets=((96, 128),)).detect_many(frames)
    for got, ref in zip(reqs, plain):
        np.testing.assert_array_equal(np.asarray(got.result.lines),
                                      np.asarray(ref.result.lines))
        np.testing.assert_array_equal(np.asarray(got.result.peaks),
                                      np.asarray(ref.result.peaks))
        np.testing.assert_array_equal(np.asarray(got.result.edges),
                                      np.asarray(ref.result.edges))


def test_config_level_render_still_delivers_overlays():
    """A service built with ``PipelineConfig(render_output=True)`` (the
    pre-QoS way to get overlays) must still return ``rendered`` for every
    request, without the per-request flag."""
    cfg = dataclasses.replace(_cfg(), render_output=True)
    svc = DetectionService(cfg, buckets=((96, 128),), batch_size=2,
                           clock=VirtualClock(), prefetch=False)
    reqs = svc.detect_many([_frame(80, 100, seed=3)])
    assert reqs[0].result.rendered is not None
    assert reqs[0].result.rendered.shape == (80, 100, 3)


# --- prefetch staging -------------------------------------------------------


def test_prefetch_loader_matches_synchronous_staging():
    loader = PrefetchStager()
    try:
        frames = [_frame(80, 100, seed=i) for i in range(4)]
        futs = [loader.stage(pad_to_bucket, f, (96, 128)) for f in frames]
        for f, fut in zip(frames, futs):
            np.testing.assert_array_equal(fut.result(),
                                          pad_to_bucket(f, (96, 128)))
    finally:
        loader.close()


def test_prefetch_loader_propagates_exceptions():
    loader = PrefetchStager()
    try:
        fut = loader.stage(pad_to_bucket, np.zeros((500, 500)), (96, 128))
        with pytest.raises(AssertionError):
            fut.result()                 # frame exceeds the bucket
    finally:
        loader.close()


# --- arbitrary interleavings (shared driver; hypothesis widens the space) ---

_SHAPES = ((80, 100), (96, 128), (100, 144), (120, 160))
_DEADLINES = (None, 0.02, 0.08, 10.0)
_REF_DET = LineDetector(_cfg())


def _run_interleaving(ops, seed):
    """Drive the same traffic schedule through a prefetch-threaded service
    and a synchronous one and check the QoS invariants:

      * every request terminates exactly once, with an explicit status
        (DONE results / QUEUE_FULL / DEADLINE_EXCEEDED partition the set);
      * crop-back stays bit-exact vs the unbatched detector on the padded
        frame for every answered request;
      * the threaded stream matches the synchronous stream bit-for-bit
        (scheduling reads the clock and the queues, never the thread).

    ``ops``: list of (shape_idx, deadline_idx, advance_ms, step_after).
    """
    rng = np.random.default_rng(seed)
    frames = [
        rng.uniform(0.0, 255.0, _SHAPES[si]).astype(np.float32)
        for si, _, _, _ in ops
    ]
    runs = []
    for prefetch in (True, False):
        clock = VirtualClock()
        svc = DetectionService(
            _cfg(), buckets=BUCKETS, batch_size=2, clock=clock,
            prefetch=prefetch, est_dispatch_s=0.01, max_queue=3,
        )
        reqs = []
        for i, (si, di, adv_ms, step_after) in enumerate(ops):
            clock.advance(adv_ms / 1000.0)
            r = DetectionRequest(uid=i, frame=frames[i],
                                 deadline_s=_DEADLINES[di])
            svc.submit(r)
            reqs.append(r)
            if step_after:
                svc.step()
                svc.drain()              # deterministic completion stamps
        svc.run()
        svc.close()
        # answered exactly once, explicit statuses partition the requests
        assert all(r.done for r in reqs)
        n_ok = sum(r.ok for r in reqs)
        assert svc.completed == n_ok
        assert (svc.completed + svc.shed_deadline
                + svc.rejected_queue_full) == len(reqs)
        for r in reqs:
            assert (r.result is not None) == r.ok
            if r.status in (RequestStatus.QUEUE_FULL,
                            RequestStatus.DEADLINE_EXCEEDED):
                assert r.missed_deadline or r.deadline_at is None
        runs.append(reqs)
    threaded, synchronous = runs
    for ra, rb in zip(threaded, synchronous):
        assert ra.status == rb.status, (ra.uid, ra.status, rb.status)
        if ra.ok:
            for field in ("lines", "valid", "peaks", "edges"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(ra.result, field)),
                    np.asarray(getattr(rb.result, field)),
                )
    # crop-back bit-exactness vs the unbatched reference
    for r in threaded:
        if not r.ok:
            continue
        padded = pad_to_bucket(r.frame, r.bucket)
        ref = crop_result(_REF_DET.detect(jnp.asarray(padded)),
                          *r.frame.shape[:2])
        np.testing.assert_array_equal(np.asarray(r.result.lines),
                                      np.asarray(ref.lines))
        np.testing.assert_array_equal(np.asarray(r.result.peaks),
                                      np.asarray(ref.peaks))
        np.testing.assert_array_equal(np.asarray(r.result.edges),
                                      np.asarray(ref.edges))


_FIXED_INTERLEAVINGS = [
    # same-bucket burst, mixed deadlines, shed via the 40ms advance
    [(1, 3, 0, False), (1, 1, 5, True), (0, 0, 0, False), (1, 2, 10, True),
     (1, 1, 40, False)],
    # cross-bucket with backpressure (max_queue=3) and a late drain
    [(3, 1, 0, False), (0, 1, 0, False), (2, 3, 0, False), (1, 0, 0, False),
     (0, 0, 50, True), (3, 3, 5, True)],
    # steady drip, no deadlines: pure throughput mode under the driver
    [(2, 0, 0, True), (2, 0, 1, True), (2, 0, 1, True), (2, 0, 1, False)],
]


@pytest.mark.parametrize("case", range(len(_FIXED_INTERLEAVINGS)))
def test_interleaved_traffic_invariants(case):
    _run_interleaving(_FIXED_INTERLEAVINGS[case], seed=case)


def test_interleaved_traffic_property():
    """Hypothesis-widened version of the fixed interleavings (skips where
    hypothesis is absent — the deterministic cases above always run)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=6, deadline=None)
    @given(
        st.lists(
            st.tuples(
                st.integers(0, len(_SHAPES) - 1),
                st.integers(0, len(_DEADLINES) - 1),
                st.integers(0, 60),
                st.booleans(),
            ),
            min_size=1, max_size=8,
        ),
        st.integers(0, 2 ** 31 - 1),
    )
    def prop(ops, seed):
        _run_interleaving(ops, seed)

    prop()
